"""HLO analysis: the trip-count-aware cost walk vs known ground truths,
including the proof that XLA's own cost_analysis counts loop bodies once."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.compat import cost_analysis
from repro.utils import collective_bytes, hlo_cost, op_histogram, shape_bytes


def test_shape_bytes():
    assert shape_bytes("f32[128,4]") == 128 * 4 * 4
    assert shape_bytes("bf16[2,3]{1,0}") == 12
    assert shape_bytes("(f32[2,2], s32[4])") == 16 + 16
    assert shape_bytes("pred[8]") == 8
    assert shape_bytes("f32[]") == 4


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_xla_counts_loop_bodies_once():
    """The motivation for hlo_cost: scan x10 reports ~1x matmul flops.
    (``repro.launch.compat.cost_analysis`` flattens the per-partition list
    jax 0.4.x returns — the ISSUE 4 port of the jax>=0.6 call site.)"""
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)

    def scanned(x, ws):
        return jax.lax.scan(lambda c, w: (jnp.dot(c, w), None), x, ws)[0]

    comp = jax.jit(scanned).lower(x, ws).compile()
    xla = cost_analysis(comp)["flops"]
    assert xla < 2 * 2 * 128**3          # ~1 matmul, NOT 10


def test_hlo_cost_scan_flops_exact():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)

    def scanned(x, ws):
        return jax.lax.scan(lambda c, w: (jnp.dot(c, w), None), x, ws)[0]

    c = hlo_cost(_compile(scanned, x, ws))
    assert c.flops == 10 * 2 * 128**3
    assert 10 in c.while_trip_counts
    assert c.unresolved_loops == 0


def test_hlo_cost_nested_loops():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)

    def nested(x, ws):
        def outer(c, w):
            def inner(ci, _):
                return jnp.dot(ci, w), None
            return jax.lax.scan(inner, c, None, length=3)[0], None
        return jax.lax.scan(outer, x, ws)[0]

    c = hlo_cost(_compile(nested, x, ws))
    assert c.flops == 15 * 2 * 64**3
    assert sorted(c.while_trip_counts) == [3, 5]


def test_hlo_cost_plain_dot():
    a = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((64, 16), jnp.float32)
    c = hlo_cost(_compile(lambda a, b: a @ b, a, b))
    assert c.flops == 2 * 32 * 64 * 16


def test_collective_parser_on_sharded_module():
    """A psum under shard_map must be found with the right byte count.
    (Mesh/shard_map go through ``repro.launch.compat`` so the same code
    runs the jax>=0.6 surface on the pinned 0.4.x wheel.)"""
    import subprocess, sys, textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        import sys
        sys.path.insert(0, "src")
        from repro.launch.compat import AxisType, make_mesh, shard_map
        from repro.utils import collective_bytes, hlo_cost
        mesh = make_mesh((4,), ("x",), axis_types=(AxisType.Auto,))
        f = shard_map(lambda a: jax.lax.psum(a, "x"), mesh=mesh,
                      in_specs=P(), out_specs=P(), axis_names={"x"},
                      check_vma=False)
        txt = jax.jit(f).lower(
            jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile().as_text()
        st = collective_bytes(txt)
        assert st.total_bytes >= 64 * 64 * 4, st
        hc = hlo_cost(txt)
        assert hc.collective_bytes >= 64 * 64 * 4
        print("OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=".")
    assert "OK" in r.stdout, r.stderr[-2000:]


def test_op_histogram():
    x = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    hist = dict(op_histogram(_compile(lambda a: a @ a + a, x, ), top=50))
    assert sum(hist.values()) > 0
