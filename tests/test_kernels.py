"""Pallas kernel sweeps: shapes x dtypes, assert_allclose vs the jnp oracle
(interpret mode executes the kernel body on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash import attention_ref, flash_attention
from repro.kernels.rwkv6 import wkv6, wkv6_ref
from repro.models.rwkv6 import wkv_chunked


FLASH_SWEEP = [
    # (B, S, T, H, KV, hd, causal, block)
    (1, 64, 64, 2, 2, 32, True, 32),
    (2, 128, 128, 4, 2, 64, True, 64),
    (1, 200, 200, 4, 4, 64, True, 64),      # non-multiple of block
    (2, 128, 256, 8, 2, 128, False, 64),    # cross lengths, GQA 4:1
    (1, 96, 96, 8, 1, 64, True, 32),        # MQA
]


@pytest.mark.parametrize("B,S,T,H,KV,hd,causal,blk", FLASH_SWEEP)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_oracle(B, S, T, H, KV, hd, causal, blk, dtype):
    rng = jax.random.PRNGKey(42)
    k1, k2, k3 = jax.random.split(rng, 3)
    q = jax.random.normal(k1, (B, S, H, hd), dtype)
    k = jax.random.normal(k2, (B, T, KV, hd), dtype)
    v = jax.random.normal(k3, (B, T, KV, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=blk, block_k=blk)
    ref = attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


WKV_SWEEP = [
    # (B, S, H, hd, chunk)
    (1, 64, 1, 16, 16),
    (2, 128, 2, 32, 32),
    (1, 256, 4, 64, 64),
    (2, 96, 2, 8, 32),
    (1, 128, 2, 64, 128),                   # single chunk == full seq
]


@pytest.mark.parametrize("B,S,H,hd,chunk", WKV_SWEEP)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_kernel_matches_oracle(B, S, H, hd, chunk, dtype):
    rng = jax.random.PRNGKey(7)
    ks = jax.random.split(rng, 6)
    r = (jax.random.normal(ks[0], (B, S, H, hd)) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (B, S, H, hd)) * 0.5).astype(dtype)
    v = (jax.random.normal(ks[2], (B, S, H, hd)) * 0.5).astype(dtype)
    logw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, hd)) * 0.5 - 2.0)
    u = jax.random.normal(ks[4], (H, hd)) * 0.3
    s0 = jax.random.normal(ks[5], (B, H, hd, hd)) * 0.2
    y_ref, s_ref = wkv6_ref(r, k, v, logw, u, s0)
    y, s = wkv6(r, k, v, logw, u, s0, chunk=chunk)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("B,S,H,hd,chunk", WKV_SWEEP[:3])
def test_wkv6_jnp_chunked_matches_oracle(B, S, H, hd, chunk):
    """The model's default (non-Pallas) chunked path is the same math."""
    rng = jax.random.PRNGKey(11)
    ks = jax.random.split(rng, 6)
    r = jax.random.normal(ks[0], (B, S, H, hd)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, hd)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, hd)) * 0.5
    logw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, hd)) * 0.5 - 2.0)
    u = jax.random.normal(ks[4], (H, hd)) * 0.3
    s0 = jax.random.normal(ks[5], (B, H, hd, hd)) * 0.2
    y_ref, s_ref = wkv6_ref(r, k, v, logw, u, s0)
    y, s = wkv_chunked(r, k, v, logw, u, s0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), atol=1e-4)


def test_wkv6_state_threading():
    """Chunked with carried state == one long sequence split in two."""
    rng = jax.random.PRNGKey(3)
    ks = jax.random.split(rng, 6)
    B, S, H, hd = 1, 128, 2, 32
    r = jax.random.normal(ks[0], (B, S, H, hd)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, hd)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, hd)) * 0.5
    logw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, hd)) * 0.5 - 2.0)
    u = jax.random.normal(ks[4], (H, hd)) * 0.3
    s0 = jnp.zeros((B, H, hd, hd))
    y_full, s_full = wkv6_ref(r, k, v, logw, u, s0)
    h = S // 2
    y1, s_mid = wkv6(r[:, :h], k[:, :h], v[:, :h], logw[:, :h], u, s0,
                     chunk=32)
    y2, s_end = wkv6(r[:, h:], k[:, h:], v[:, h:], logw[:, h:], u, s_mid,
                     chunk=32)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_end), np.asarray(s_full),
                               atol=1e-4)
