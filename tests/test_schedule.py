"""Discrete-event pipeline sim vs the analytical Eq. (14) (schedule.py)."""

import pytest

pytest.importorskip("hypothesis")  # optional dev dep: skip module, not error
from hypothesis import given, settings, strategies as st

from repro.core import SplitSolution, breakdown, num_fills, total_latency
from repro.pipeline import simulate, simulate_from_breakdown
from conftest import small_instance

pos = st.floats(0.01, 5.0, allow_nan=False)


@settings(max_examples=40, deadline=None)
@given(fp=st.lists(pos, min_size=2, max_size=5),
       q=st.integers(1, 30), data=st.data())
def test_sim_equals_analytic_separate_engines(fp, q, data):
    """Identical jobs through a linear chain of FIFO resources: makespan
    == T_f + (Q-1) * max resource time — the paper's Eq. (14) exactly."""
    k = len(fp)
    bp = data.draw(st.lists(pos, min_size=k, max_size=k))
    fwd = data.draw(st.lists(pos, min_size=k - 1, max_size=k - 1))
    bwd = data.draw(st.lists(pos, min_size=k - 1, max_size=k - 1))
    r = simulate(fp, bp, fwd, bwd, q)
    assert r.makespan == pytest.approx(r.analytic, rel=1e-12)


@settings(max_examples=20, deadline=None)
@given(fp=st.lists(pos, min_size=2, max_size=4), q=st.integers(2, 20),
       data=st.data())
def test_shared_engine_never_faster(fp, q, data):
    """A node whose FP and BP share one engine can only be slower than the
    paper's separate-resource model (quantifies the model's optimism)."""
    k = len(fp)
    bp = data.draw(st.lists(pos, min_size=k, max_size=k))
    fwd = data.draw(st.lists(pos, min_size=k - 1, max_size=k - 1))
    bwd = data.draw(st.lists(pos, min_size=k - 1, max_size=k - 1))
    sep = simulate(fp, bp, fwd, bwd, q)
    shared = simulate(fp, bp, fwd, bwd, q, shared_engine=True)
    assert shared.makespan >= sep.makespan - 1e-12


def test_sim_validates_eq14_on_real_instance():
    prof, net = small_instance(3)
    sol = SplitSolution(cuts=(2, 4, 6), placement=(0, 1, 2))
    b, B = 8, 64
    q = num_fills(B, b) + 1
    r = simulate_from_breakdown(breakdown(prof, net, sol, b), q)
    # with no co-located submodels, Eq. (14) == event-sim makespan
    assert r.makespan == pytest.approx(
        total_latency(prof, net, sol, b, B), rel=1e-9)


def test_memory_factors():
    r = simulate([1, 1, 1], [1, 1, 1], [0.1, 0.1], [0.1, 0.1], 12)
    assert r.memory_factor["gpipe"][0] == 12
    assert r.memory_factor["1f1b"][0] == 3       # K - k in-flight
    assert r.memory_factor["1f1b"][2] == 1
