"""Executor equivalences: micro-batched grads == full batch; the SL
executor trains (loss decreases) and charges the analytic latency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ours, vgg16_profile, make_edge_network
from repro.data import classification_batches, client_datasets
from repro.models import vgg as vgg_lib
from repro.pipeline import (LinkHooks, SplitLearningExecutor,
                            microbatch_grads, split_batch)


def test_microbatch_grads_equal_full_batch():
    """The paper's synchronous-SGD guarantee (Fig. 4: same convergence)."""
    rng = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(rng, (8, 4)),
              "b": jnp.zeros((4,))}

    def loss_fn(p, batch):
        logits = batch["x"] @ p["w"] + p["b"]
        return jnp.mean((logits - batch["y"]) ** 2)

    batch = {"x": jax.random.normal(rng, (16, 8)),
             "y": jax.random.normal(rng, (16, 4))}
    l_full, g_full = jax.value_and_grad(loss_fn)(params, batch)
    for q in (1, 2, 4, 8, 16):
        l_mb, g_mb = microbatch_grads(loss_fn, params, batch, q)
        assert float(l_mb) == pytest.approx(float(l_full), rel=1e-6)
        for a, b in zip(jax.tree.leaves(g_mb), jax.tree.leaves(g_full)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6)


def test_split_batch_shapes():
    batch = {"x": jnp.zeros((12, 3)), "y": jnp.zeros((12,))}
    mb = split_batch(batch, 4)
    assert mb["x"].shape == (4, 3, 3)
    assert mb["y"].shape == (4, 3)


@pytest.fixture(scope="module")
def sl_setup():
    profile = vgg16_profile(work_units="bytes")
    net = make_edge_network(num_servers=4, num_clients=2, seed=3,
                            kappa=1 / 32.0)
    plan = ours(profile, net, B=16, b0=4)
    return profile, net, plan


def test_sl_executor_trains(sl_setup):
    profile, net, plan = sl_setup
    ex = SplitLearningExecutor(plan, profile, net, seed=0)
    batch = {k: jnp.asarray(v)
             for k, v in next(classification_batches(batch=16, seed=0)).items()}
    # overfit one batch: monotone-ish loss decrease is guaranteed
    # (lr retuned for the He-gain VGG init — 0.05 overshoots with
    # properly-scaled gradients)
    losses = [ex.train_round(batch, lr=0.01) for _ in range(3)]
    assert losses[-1] < losses[0]
    # the sim clock advances by the plan latency per round
    assert ex.simulated_time == pytest.approx(3 * plan.L_t)


def test_sl_executor_with_compression(sl_setup):
    from repro.compression import make_link_hooks
    profile, net, plan = sl_setup
    ex = SplitLearningExecutor(plan, profile, net, seed=0,
                               hooks=make_link_hooks("int8"))
    batch = {k: jnp.asarray(v)
             for k, v in next(classification_batches(batch=16, seed=1)).items()}
    losses = [ex.train_round(batch, lr=0.01) for _ in range(3)]
    assert losses[-1] < losses[0]          # int8 links don't break training


def test_vgg_stage_chain_equals_full_forward():
    from repro.pipeline import vgg_stages_from_cuts, split_vgg_params
    rng = jax.random.PRNGKey(1)
    params = vgg_lib.init_params(rng)
    x = jax.random.normal(rng, (2, 32, 32, 3))
    full = vgg_lib.forward(params, x)
    cuts = (3, 9, 16)
    stages = vgg_stages_from_cuts(cuts)
    parts = split_vgg_params(params, cuts)
    y = x
    for st, sp in zip(stages, parts):
        y = st.forward(sp, y)
    np.testing.assert_allclose(np.asarray(full), np.asarray(y), atol=1e-5)


def test_client_datasets_partitions():
    ds = client_datasets(4, samples=512, iid=False, alpha=0.3, seed=0)
    assert len(ds) == 4
    total = sum(len(d.labels) for d in ds)
    assert total == 512
    draw = ds[0].draw(8)
    assert draw["images"].shape == (8, 32, 32, 3)
