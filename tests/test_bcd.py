"""Algorithm 2 (BCD) — convergence, monotonicity, near-optimality (Fig. 7)."""

import math

import pytest

pytest.importorskip("hypothesis")  # optional dev dep: skip module, not error
from hypothesis import given, settings, strategies as st

from repro.core import (bcd_solve, exhaustive_joint, no_pipeline, ours,
                        rc_op, rp_oc, total_latency, validate_solution)
from conftest import small_instance


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 100))
def test_bcd_converges_and_is_monotone(seed):
    prof, net = small_instance(seed, num_layers=6, num_servers=3)
    plan = bcd_solve(prof, net, B=128, b0=16)
    if not plan.feasible:
        return
    assert plan.iterations <= 12
    ls = [h[0] for h in plan.history]
    for a, b in zip(ls, ls[1:]):        # L_t non-increasing per iteration
        assert b <= a * (1 + 1e-6)
    validate_solution(plan.solution, prof, net)
    assert plan.L_t == pytest.approx(
        total_latency(prof, net, plan.solution, plan.b, plan.B), rel=1e-9)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 40))
def test_bcd_near_optimal(seed):
    """Fig. 7(a): BCD within 10% of the exhaustive-over-b optimum."""
    prof, net = small_instance(seed, num_layers=5, num_servers=3)
    plan = bcd_solve(prof, net, B=64, b0=8)
    opt = exhaustive_joint(prof, net, B=64, b_step=1)
    if plan.feasible and opt.feasible:
        assert plan.L_t <= opt.L_t * 1.10 + 1e-9
        assert opt.L_t <= plan.L_t * (1 + 1e-9)   # optimality of the oracle


def test_pipelining_beats_no_pipeline(vgg_profile, paper_network):
    """Fig. 1(b): pipelined SL strictly dominates the no-pipeline optimum."""
    p = ours(vgg_profile, paper_network, B=512, b0=20)
    np_ = no_pipeline(vgg_profile, paper_network, B=512)
    assert p.feasible and np_.feasible
    assert p.L_t < np_.L_t
    # the paper reports ~3-7x; structure varies by draw — require >= 1.5x
    assert np_.L_t / p.L_t >= 1.5


def test_ours_beats_random_baselines(vgg_profile, paper_network):
    p = ours(vgg_profile, paper_network, B=512, b0=20)
    rc = rc_op(vgg_profile, paper_network, B=512, seed=7)
    rp = rp_oc(vgg_profile, paper_network, B=512, seed=7)
    assert p.L_t <= rc.L_t * (1 + 1e-9)
    assert p.L_t <= rp.L_t * (1 + 1e-9)


def test_bcd_runtime_tracks(paper_network, vgg_profile):
    plan = bcd_solve(vgg_profile, paper_network, B=512)
    assert plan.solve_seconds < 60.0       # Fig. 7(b): BCD stays fast
    assert plan.num_microbatches >= 1
