"""Algorithm 2 (BCD) — convergence, monotonicity, near-optimality (Fig. 7)."""

import math

import pytest

pytest.importorskip("hypothesis")  # optional dev dep: skip module, not error
from hypothesis import given, settings, strategies as st

from repro.core import (ClosedForm, bcd_solve, exhaustive_joint, no_pipeline,
                        ours, rc_op, rp_oc, total_latency, validate_solution)
from conftest import small_instance


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 100))
def test_bcd_converges_and_is_monotone(seed):
    prof, net = small_instance(seed, num_layers=6, num_servers=3)
    plan = bcd_solve(prof, net, B=128, b0=16)
    if not plan.feasible:
        return
    assert plan.iterations <= 12
    ls = [h[0] for h in plan.history]
    for a, b in zip(ls, ls[1:]):        # L_t non-increasing per iteration
        assert b <= a * (1 + 1e-6)
    validate_solution(plan.solution, prof, net)
    assert plan.L_t == pytest.approx(
        total_latency(prof, net, plan.solution, plan.b, plan.B), rel=1e-9)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 100), B=st.sampled_from([48, 96, 128]))
def test_cost_model_closed_form_is_bit_identical_default(seed, B):
    """The ISSUE 4 contract: ``cost_model=ClosedForm()`` must reproduce the
    default path bit-for-bit — objective, cuts, placement, b, L_t — on the
    randomized cross-check grid (same grid family as the tests above)."""
    prof, net = small_instance(seed, num_layers=6, num_servers=3)
    p0 = bcd_solve(prof, net, B=B, b0=12)
    p1 = bcd_solve(prof, net, B=B, b0=12, cost_model=ClosedForm())
    assert p0.feasible == p1.feasible
    if p0.feasible:
        assert p0.objective == p1.objective
        assert p0.solution.cuts == p1.solution.cuts
        assert p0.solution.placement == p1.solution.placement
        assert p0.b == p1.b and p0.L_t == p1.L_t
        assert p0.history == p1.history
    e0 = exhaustive_joint(prof, net, B=min(B, 48))
    e1 = exhaustive_joint(prof, net, B=min(B, 48), cost_model=ClosedForm())
    assert (e0.feasible, e0.b, e0.L_t, e0.solution) == \
        (e1.feasible, e1.b, e1.L_t, e1.solution)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 40))
def test_bcd_near_optimal(seed):
    """Fig. 7(a): BCD within 10% of the exhaustive-over-b optimum."""
    prof, net = small_instance(seed, num_layers=5, num_servers=3)
    plan = bcd_solve(prof, net, B=64, b0=8)
    opt = exhaustive_joint(prof, net, B=64, b_step=1)
    if plan.feasible and opt.feasible:
        assert plan.L_t <= opt.L_t * 1.10 + 1e-9
        assert opt.L_t <= plan.L_t * (1 + 1e-9)   # optimality of the oracle


def test_pipelining_beats_no_pipeline(vgg_profile, paper_network):
    """Fig. 1(b): pipelined SL strictly dominates the no-pipeline optimum."""
    p = ours(vgg_profile, paper_network, B=512, b0=20)
    np_ = no_pipeline(vgg_profile, paper_network, B=512)
    assert p.feasible and np_.feasible
    assert p.L_t < np_.L_t
    # the paper reports ~3-7x; structure varies by draw — require >= 1.5x
    assert np_.L_t / p.L_t >= 1.5


def test_ours_beats_random_baselines(vgg_profile, paper_network):
    p = ours(vgg_profile, paper_network, B=512, b0=20)
    rc = rc_op(vgg_profile, paper_network, B=512, seed=7)
    rp = rp_oc(vgg_profile, paper_network, B=512, seed=7)
    assert p.L_t <= rc.L_t * (1 + 1e-9)
    assert p.L_t <= rp.L_t * (1 + 1e-9)


def test_bcd_runtime_tracks(paper_network, vgg_profile):
    plan = bcd_solve(vgg_profile, paper_network, B=512)
    assert plan.solve_seconds < 60.0       # Fig. 7(b): BCD stays fast
    assert plan.num_microbatches >= 1
