"""benchmarks.sweep_grid — grid smoke + the standing engine-speed budget:
a 10k-micro-batch x 100-node deterministic scenario must simulate in < 1 s
(ISSUE 2 acceptance; asserted loosely via best-of-N wall clock)."""

import csv
import os
import time

import numpy as np
import pytest

from benchmarks import sweep_grid
from repro.core import fill_latency, pipeline_interval
from repro.sim import simulate_plan, vectorizable


def test_grid_smoke_emits_csv():
    rows = sweep_grid.run_grid(topologies=("mesh",), cvs=(0.0, 0.2),
                               B=64, b0=8)
    assert len(rows) == 4                       # 1 topo x 2 cv x 2 policies
    from benchmarks.common import RESULTS_DIR
    path = os.path.join(RESULTS_DIR, "sweep_grid.csv")
    with open(path) as f:
        got = list(csv.reader(f))
    assert got[0][0] == "topology" and len(got) == 5
    # every cell — deterministic AND fluctuation — now runs vectorized:
    # the trace generalization removed the heap fallback (run_grid itself
    # asserts the cv > 0 cells' coverage; checked per cell here too)
    by = {(r[1], r[2]): (r[3], r[4]) for r in rows}
    assert by[(0.0, "fifo")][0] == "vectorized"
    assert by[(0.2, "fifo")][0] == "vectorized"
    assert "trace" in by[(0.2, "fifo")][1]
    assert by[(0.2, "1f1b")][0] == "vectorized"


def test_scale_smoke_emits_csv():
    rows = sweep_grid.run_scale(cells=((10, 100),), repeats=1)
    assert len(rows) == 2
    for r in rows:
        assert np.isfinite(r[4]) and r[6] >= 0.0


def test_scale_instance_matches_eq14():
    """The scaling scenario is a legit distinct-placement chain: the
    vectorized FIFO makespan must equal the closed form exactly."""
    prof, net, sol, b, _ = sweep_grid.scale_instance(20, 500)
    assert vectorizable(prof, net, sol, b)
    rep = simulate_plan(prof, net, sol, b, num_microbatches=500,
                        engine="vectorized")
    ana = (fill_latency(prof, net, sol, b)
           + 499 * pipeline_interval(prof, net, sol, b))
    assert rep.L_t == pytest.approx(float(ana), rel=1e-9)


@pytest.mark.parametrize("policy", ["fifo", "1f1b"])
def test_10k_microbatch_100_node_under_one_second(policy):
    """The ISSUE 2 engine-speed budget (~4M task executions).  Loose:
    best-of-3 wall clock, and the measured budget is ~0.15 s so a slow CI
    box has ~6x headroom before this trips."""
    prof, net, sol, b, Q = sweep_grid.scale_instance(100, 10_000)
    best = float("inf")
    rep = None
    for _ in range(3):
        t0 = time.perf_counter()
        rep = simulate_plan(prof, net, sol, b, num_microbatches=Q,
                            policy=policy, engine="vectorized")
        best = min(best, time.perf_counter() - t0)
    assert rep.num_microbatches == 10_000
    assert np.isfinite(rep.L_t) and rep.L_t > 0
    assert np.all(np.diff(rep.mb_complete) > -1e-9)
    assert best < 1.0, f"{policy} took {best:.3f}s for 10k x 100"
