"""Fault tolerance: failure -> replan feasibility, straggler mitigation via
Theorem 1, rate-change replanning, remap-across-failure properties, and the
coordinator's solve/eval-error telemetry."""

import dataclasses
import math

import numpy as np
import pytest

from repro import obs
from repro.core import total_latency, validate_solution
from repro.ft import Coordinator, NodeFailure, RateChange, Straggler
from conftest import small_instance


@pytest.fixture
def coord():
    prof, net = small_instance(5, num_layers=6, num_servers=4)
    return Coordinator(prof, net, B=128), prof


def test_node_failure_replans_feasible(coord):
    c, prof = coord
    assert c.plan.feasible
    failed_server = c.plan.solution.placement[-1]
    out = c.apply(NodeFailure(server=failed_server))
    assert out.action == "replan"
    assert c.plan.feasible
    validate_solution(c.plan.solution, prof, c.net)
    # the failed node is gone from the new placement universe
    assert all(p < len(c.net.nodes) for p in c.plan.solution.placement)


def test_straggler_cheap_path_keeps_placement(coord):
    c, prof = coord
    sol_before = c.plan.solution
    node = c.plan.solution.placement[-1]
    out = c.apply(Straggler(node=node, slowdown=1.5))
    assert out.action in ("microbatch", "replan")
    if out.action == "microbatch":
        assert c.plan.solution == sol_before      # no weight movement
    # latency under the new (slower) conditions is finite + consistent
    assert math.isfinite(c.plan.L_t)
    assert c.plan.L_t == pytest.approx(
        total_latency(prof, c.net, c.plan.solution, c.plan.b, c.plan.B),
        rel=1e-9)


def test_severe_straggler_forces_replan(coord):
    c, prof = coord
    node = c.plan.solution.placement[-1]
    out = c.apply(Straggler(node=node, slowdown=50.0))
    # a 50x-slower node should be routed around (or at minimum replanned)
    assert out.action == "replan" or node not in c.plan.solution.placement \
        or c.plan.feasible


def test_rate_change_replans(coord):
    c, _ = coord
    L_before = c.plan.L_t
    out = c.apply(RateChange(n_from=1, n_to=2, factor=0.05))
    assert out.action == "replan"
    assert c.plan.feasible


def test_replan_latency_not_worse_than_fresh(coord):
    """Replanning after an event matches a from-scratch BCD solve."""
    from repro.core import bcd_solve
    c, prof = coord
    c.apply(NodeFailure(server=1))
    fresh = bcd_solve(prof, c.net, 128)
    assert c.plan.L_t <= fresh.L_t * 1.05 + 1e-9


def test_event_log(coord):
    c, _ = coord
    c.apply(Straggler(node=1, slowdown=2.0))
    c.apply(RateChange(1, 2, 0.5))
    assert len(c.events) == 2


# ---------------------------------------------------------------------------
# Straggler cheap path: the full solve is gated (satellite: saved solves)
# ---------------------------------------------------------------------------

def test_mild_straggler_skips_full_solve(coord):
    """A barely-there straggler is fixed by the Theorem-1 micro-batch
    re-solve alone: the cheap path lands within the gain threshold of the
    pre-event latency (a lower bound on what a fresh BCD could reach, since
    the straggler only removed capacity), so NO full solve runs."""
    c, _ = coord
    node = c.plan.solution.placement[-1]
    with obs.enabled_scope():
        obs.reset()
        out = c.apply(Straggler(node=node, slowdown=1.01))
        assert out.action == "microbatch"
        assert obs.counter("ft.full_solve_saved") == 1
        assert obs.counter("ft.full_solves") == 0
        assert obs.counter("ft.replans") == 1


def test_severe_straggler_still_pays_full_solve(coord):
    """Slowing the client-side node 50x cannot be absorbed by a micro-batch
    re-solve (cheap_L blows past the old_L gate), so the full BCD runs."""
    c, _ = coord
    node = c.plan.solution.placement[0]
    with obs.enabled_scope():
        obs.reset()
        c.apply(Straggler(node=node, slowdown=50.0))
        assert obs.counter("ft.full_solves") == 1


# ---------------------------------------------------------------------------
# Exception narrowing (satellite): expected infeasibility counted, bugs raise
# ---------------------------------------------------------------------------

class _BrokenModel:
    """Cost-model stub whose evaluate raises a chosen exception type."""
    name = "broken"

    def __init__(self, exc):
        self.exc = exc

    def evaluate(self, *a, **k):
        raise self.exc("boom")

    def memory_feasible(self, *a, **k):
        return True


def test_eval_errors_counted_for_expected_infeasibility(coord):
    c, _ = coord
    c.cost_model = _BrokenModel(ValueError)
    with obs.enabled_scope():
        obs.reset()
        assert c._current_latency() == math.inf
        assert c._evaluate_candidate(c.net, c.plan.solution,
                                     c.plan.b) == math.inf
        assert obs.counter("ft.eval_errors") == 2


def test_programming_errors_are_not_masked(coord):
    c, _ = coord
    c.cost_model = _BrokenModel(RuntimeError)
    with pytest.raises(RuntimeError):
        c._current_latency()
    c.cost_model = _BrokenModel(TypeError)
    with pytest.raises(TypeError):
        c._evaluate_candidate(c.net, c.plan.solution, c.plan.b)


# ---------------------------------------------------------------------------
# Remap-across-failure properties (satellite): hypothesis suite + seeded twin
# ---------------------------------------------------------------------------

def _remap_instance(seed: int):
    from repro.sim.validate import random_instance
    for s in range(seed, seed + 40):
        prof, net, sol, b, B = random_instance(s)
        if len(net.nodes) >= 4:
            return prof, net, sol, b, B
    raise RuntimeError("no >=4-node instance found")


def _check_remap_properties(prof, net, sol, b, B, server):
    remapped = Coordinator._remap_across_failure(sol, server)
    if server in sol.placement:
        # hosting-server failure: its submodels must move, no ride-out
        assert remapped is None
        return
    degraded = net.degraded([server])
    # the remapped placement names the SAME physical nodes
    assert [degraded.nodes[p] for p in remapped.placement] == \
        [net.nodes[p] for p in sol.placement]
    # indices above the dropped server shift down by exactly one
    assert tuple(remapped.placement) == tuple(
        p - 1 if p > server else p for p in sol.placement)
    # degraded() keeps the effective-rate submatrix, so the closed-form
    # ride-out objective is invariant under the renumbering
    L_old = total_latency(prof, net, sol, b, B)
    L_new = total_latency(prof, degraded, remapped, b, B)
    assert L_new == pytest.approx(L_old, rel=1e-12)


def test_remap_across_failure_seeded_sweep():
    """Deterministic twin of the hypothesis property (runs everywhere)."""
    for seed in (0, 7, 23):
        prof, net, sol, b, B = _remap_instance(seed)
        for server in range(1, len(net.nodes)):
            _check_remap_properties(prof, net, sol, b, B, server)


def test_remap_across_failure_hypothesis():
    pytest.importorskip("hypothesis")  # optional dev dep
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=10_000),
           pick=st.integers(min_value=0, max_value=10_000))
    def prop(seed, pick):
        prof, net, sol, b, B = _remap_instance(seed)
        server = 1 + pick % (len(net.nodes) - 1)
        _check_remap_properties(prof, net, sol, b, B, server)

    prop()


def test_absorbed_failure_keeps_plan_when_not_hosting(coord):
    """Absorbing a NodeFailure of a non-hosting server remaps indices and
    keeps the incumbent objective (closed-form invariance), paying neither
    a solve nor a restore."""
    c, prof = coord
    spare = next(s for s in range(1, len(c.net.nodes))
                 if s not in c.plan.solution.placement)
    L_before = c.plan.objective
    out = c.absorb(NodeFailure(server=spare))
    assert out.action == "absorb"
    assert out.restore_seconds == 0.0
    assert c.plan.objective == pytest.approx(L_before, rel=1e-12)
    validate_solution(c.plan.solution, prof, c.net)


def test_absorbed_failure_escalates_when_hosting(coord):
    """Absorbing a failure of a hosting server is impossible — the absorb
    escalates to a forced full replan (and pays the restore)."""
    c, _ = coord
    c.restore_cost = 0.25
    hosting = c.plan.solution.placement[-1]
    with obs.enabled_scope():
        obs.reset()
        out = c.absorb(NodeFailure(server=hosting))
        assert out.action == "replan"
        assert out.restore_seconds == 0.25
        assert out.ride_out_latency == math.inf
        assert obs.counter("ft.absorb_escalated") == 1
