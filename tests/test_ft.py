"""Fault tolerance: failure -> replan feasibility, straggler mitigation via
Theorem 1, rate-change replanning."""

import math

import pytest

from repro.core import total_latency, validate_solution
from repro.ft import Coordinator, NodeFailure, RateChange, Straggler
from conftest import small_instance


@pytest.fixture
def coord():
    prof, net = small_instance(5, num_layers=6, num_servers=4)
    return Coordinator(prof, net, B=128), prof


def test_node_failure_replans_feasible(coord):
    c, prof = coord
    assert c.plan.feasible
    failed_server = c.plan.solution.placement[-1]
    out = c.apply(NodeFailure(server=failed_server))
    assert out.action == "replan"
    assert c.plan.feasible
    validate_solution(c.plan.solution, prof, c.net)
    # the failed node is gone from the new placement universe
    assert all(p < len(c.net.nodes) for p in c.plan.solution.placement)


def test_straggler_cheap_path_keeps_placement(coord):
    c, prof = coord
    sol_before = c.plan.solution
    node = c.plan.solution.placement[-1]
    out = c.apply(Straggler(node=node, slowdown=1.5))
    assert out.action in ("microbatch", "replan")
    if out.action == "microbatch":
        assert c.plan.solution == sol_before      # no weight movement
    # latency under the new (slower) conditions is finite + consistent
    assert math.isfinite(c.plan.L_t)
    assert c.plan.L_t == pytest.approx(
        total_latency(prof, c.net, c.plan.solution, c.plan.b, c.plan.B),
        rel=1e-9)


def test_severe_straggler_forces_replan(coord):
    c, prof = coord
    node = c.plan.solution.placement[-1]
    out = c.apply(Straggler(node=node, slowdown=50.0))
    # a 50x-slower node should be routed around (or at minimum replanned)
    assert out.action == "replan" or node not in c.plan.solution.placement \
        or c.plan.feasible


def test_rate_change_replans(coord):
    c, _ = coord
    L_before = c.plan.L_t
    out = c.apply(RateChange(n_from=1, n_to=2, factor=0.05))
    assert out.action == "replan"
    assert c.plan.feasible


def test_replan_latency_not_worse_than_fresh(coord):
    """Replanning after an event matches a from-scratch BCD solve."""
    from repro.core import bcd_solve
    c, prof = coord
    c.apply(NodeFailure(server=1))
    fresh = bcd_solve(prof, c.net, 128)
    assert c.plan.L_t <= fresh.L_t * 1.05 + 1e-9


def test_event_log(coord):
    c, _ = coord
    c.apply(Straggler(node=1, slowdown=2.0))
    c.apply(RateChange(1, 2, 0.5))
    assert len(c.events) == 2
