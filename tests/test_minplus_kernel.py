"""Pallas min-plus kernel parity (ISSUE 9).

Marked ``pallas``: wherever the Pallas lowering toolchain is missing these
tests *skip*, never fail — the kernel is an optional backend and the numpy
``_sweep`` stays the contract-bearing reference.
"""

import numpy as np
import pytest

pytest.importorskip("jax")

from repro.core import Planner, build_graph
from repro.core.shortest_path import _LayeredDP
from conftest import same_msp_result as _same_result, small_instance

minplus = pytest.importorskip("repro.kernels.minplus")

pytestmark = pytest.mark.pallas

if not minplus.pallas_available():         # pragma: no cover
    pytest.skip("pallas unavailable on this host", allow_module_level=True)


def _dp(seed, b=8, K=4):
    prof, net = small_instance(seed, num_layers=6, num_servers=3)
    return _LayeredDP(build_graph(prof, net, b), K)


@pytest.mark.parametrize("seed", [0, 1, 5])
@pytest.mark.parametrize("mode", ["sum", "max"])
def test_kernel_matches_ref_oracle(seed, mode):
    dp = _dp(seed)
    ts = dp.all_betas()[::3]
    args = (dp._Ccom[0], dp._Bcom[0], dp._Sseg[0], dp._Bseg[0],
            dp._src_cost[0], dp._src_beta[0], dp.K, ts)
    got = minplus.sweep_minplus(*args, mode=mode)
    want = minplus.sweep_ref(*args, mode=mode)
    finite = np.isfinite(want)
    assert (finite == np.isfinite(got)).all()
    assert np.allclose(got[finite], want[finite], rtol=1e-4)


@pytest.mark.parametrize("seed", [0, 2])
def test_kernel_matches_numpy_sweep(seed):
    """The end contract: kernel dist values match ``_LayeredDP.dist_at``
    within the float32 tolerance (bit-exact when x64 is on)."""
    dp = _dp(seed)
    ts = dp.all_betas()[::2]
    got = minplus.sweep_minplus(dp._Ccom[0], dp._Bcom[0], dp._Sseg[0],
                                dp._Bseg[0], dp._src_cost[0],
                                dp._src_beta[0], dp.K, ts)
    want = dp.dist_at(ts)
    finite = np.isfinite(want)
    assert (finite == np.isfinite(got)).all()
    assert np.allclose(got[finite], want[finite], rtol=1e-4)


def test_planner_backend_pallas_matches_numpy():
    prof, net = small_instance(3, num_layers=5, num_servers=3)
    for b in (4, 12):
        r_np = Planner(prof, net).solve(b, 32, solver="batched")
        r_pl = Planner(prof, net).solve(b, 32, solver="batched",
                                        backend="pallas")
        assert r_np.feasible == r_pl.feasible
        if r_np.feasible:
            # the window argmin may tie-break differently under float32,
            # but the repriced objective must agree to kernel tolerance
            assert r_pl.objective == pytest.approx(r_np.objective, rel=1e-4)
