"""ISSUE 3 — threshold-batched Algorithm 1: deterministic coverage.

The hypothesis property tests live in tests/test_msp.py (optional dev dep);
this module keeps the scan == batched equivalence contract, the sweep
accounting, the Planner reuse guarantees and the optional jax backend under
test with no optional dependencies.
"""

import math

import numpy as np
import pytest

from repro.core import (GraphFactory, Planner, brute_force_msp, build_graph,
                        make_edge_network, random_profile, solve_msp)
from repro.core.latency import (bp_latency, bwd_bytes, comm_latency,
                                fp_latency, fwd_bytes)
from conftest import same_msp_result as _same_result, small_instance


@pytest.mark.parametrize("seed", range(0, 40, 4))
def test_batched_equals_scan_randomized(seed):
    """Bit-identical (objective, cuts, placement, T_1) across solvers, and
    both optimal vs brute force."""
    prof, net = small_instance(seed, num_layers=5, num_servers=3)
    for b, B in ((4, 32), (8, 64), (64, 64)):
        r_scan = solve_msp(prof, net, b, B, K=3, solver="scan")
        r_bat = solve_msp(prof, net, b, B, K=3, solver="batched")
        assert _same_result(r_scan, r_bat), (seed, b, B)
        bf, _ = brute_force_msp(prof, net, b, B, K=3, objective="paper")
        if r_scan.feasible:
            assert r_scan.objective == pytest.approx(bf, rel=1e-9)
        else:
            assert bf == math.inf


@pytest.mark.parametrize("seed", (1, 5, 9))
def test_batched_equals_scan_restricted(seed):
    rng = np.random.default_rng(seed)
    prof, net = small_instance(seed, num_layers=6, num_servers=3)
    cuts = tuple(sorted(rng.choice(np.arange(1, 6), 2, replace=False))) + (6,)
    placement = (0,) + tuple(
        int(x) for x in rng.permutation(list(net.server_indices()))[:2])
    for kw in ({"restrict_cuts": cuts, "K": 3},
               {"restrict_placement": placement, "K": 3}):
        r_scan = solve_msp(prof, net, 8, 64, solver="scan", **kw)
        r_bat = solve_msp(prof, net, 8, 64, solver="batched", **kw)
        assert _same_result(r_scan, r_bat), (seed, kw)


def test_batched_equals_scan_memory_edges():
    """Infeasible and client-only-path instances agree across solvers."""
    prof = random_profile(np.random.default_rng(1), 4)
    # servers memoryless, roomy client -> the client-only path must win
    net = make_edge_network(num_servers=2, num_clients=2, seed=2,
                            client_mem=1e18, mem_range=(1.0, 1.0))
    r_scan = solve_msp(prof, net, 8, 64, solver="scan")
    r_bat = solve_msp(prof, net, 8, 64, solver="batched")
    assert r_scan.feasible and _same_result(r_scan, r_bat)
    assert r_scan.solution.placement == (0,)
    # nothing fits anywhere -> both infeasible
    net = make_edge_network(num_servers=2, num_clients=2, seed=2,
                            client_mem=1.0, mem_range=(1.0, 1.0))
    r_scan = solve_msp(prof, net, 8, 64, solver="scan")
    r_bat = solve_msp(prof, net, 8, 64, solver="batched")
    assert not r_scan.feasible and not r_bat.feasible


def test_solve_many_matches_per_b_solve():
    """Planner.solve_many (the stacked b-sweep under exhaustive_joint) is
    bit-identical to independent per-b batched solves."""
    for seed in (0, 3, 7):
        prof, net = small_instance(seed, num_layers=6, num_servers=4)
        pl = Planner(prof, net)
        B = 32
        bs = list(range(1, B + 1))
        for b, many in zip(bs, pl.solve_many(bs, B)):
            solo = pl.solve(b, B, solver="batched")
            assert _same_result(many, solo), (seed, b)


def test_sweep_accounting(vgg_profile, paper_network):
    """thresholds_scanned counts ALL DP sweeps: the scan solver pays the
    full-graph run + every binary-search probe + every scanned threshold;
    a batched multi-threshold kernel invocation counts as 1."""
    r_scan = solve_msp(vgg_profile, paper_network, 16, 512, solver="scan")
    r_bat = solve_msp(vgg_profile, paper_network, 16, 512, solver="batched")
    # scan: 1 (full graph) + ceil(log2(|B|)) probes + >= 1 scanned threshold
    assert r_scan.thresholds_scanned >= 3
    # batched: full + min-max + beta* probe + window kernel (+ reconstruct)
    assert 4 <= r_bat.thresholds_scanned <= 5
    assert r_bat.thresholds_scanned < r_scan.thresholds_scanned
    assert r_scan.solver == "scan" and r_bat.solver == "batched"


def test_planner_reuses_graphs_and_dp_buffers(vgg_profile, paper_network):
    """The Planner caches GraphFactory output per b and rebinds DP buffers
    instead of rebuilding them (ISSUE 3 reuse contract)."""
    pl = Planner(vgg_profile, paper_network)
    r1 = pl.solve(16, 512)
    g1 = pl.graph(16)
    r2 = pl.solve(16, 512)
    assert pl.graph(16) is g1          # same cached graph object
    assert _same_result(r1, r2)
    dp_keys = set(pl._dps)
    pl.solve(8, 512)                   # new b: same DP buffers, rebound
    assert set(pl._dps) == dp_keys


def test_graph_factory_matches_scalar_latency_model(vgg_profile,
                                                    paper_network):
    """GraphFactory's broadcast assembly reproduces the per-edge scalar
    Eqs. (2)-(11) used by the latency module (the old per-entry loops)."""
    prof, net = vgg_profile, paper_network
    b = 16
    g = GraphFactory(prof, net).graph(b)
    rng = np.random.default_rng(0)
    I, N = prof.num_layers, len(net.nodes)
    for _ in range(64):
        n = int(rng.integers(0, N))
        i = int(rng.integers(0, I))
        j = int(rng.integers(i + 1, I + 1))
        fp = fp_latency(prof, net, i, j, n, b)
        bp = bp_latency(prof, net, i, j, n, b)
        if np.isfinite(g.seg_cost[n, i, j]):
            assert g.seg_cost[n, i, j] == pytest.approx(fp + bp, rel=1e-12)
            assert g.seg_beta[n, i, j] == pytest.approx(max(fp, bp), rel=1e-12)
        cut = int(rng.integers(1, I + 1))
        m = int(rng.integers(0, N))
        if m != n:
            fb = fwd_bytes(prof, net, cut, b, from_client=(n == 0))
            gb = bwd_bytes(prof, net, cut, b, to_client=(n == 0))
            want = comm_latency(net, n, m, fb) + comm_latency(net, m, n, gb)
            assert g.comm_cost[cut, n, m] == pytest.approx(want, rel=1e-12)


def test_jax_backend_matches_numpy(vgg_profile, paper_network):
    """Optional jax.jit/vmap backend of the batched window sweep."""
    pytest.importorskip("jax")
    from repro.core.shortest_path import _LayeredDP
    g = build_graph(vgg_profile, paper_network, 16)
    dp = _LayeredDP(g, 7)
    betas = dp.all_betas()
    ts = betas[:: max(1, len(betas) // 32)]
    d_np = dp.dist_at(ts, backend="numpy")
    d_jx = dp.dist_at(ts, backend="jax")
    finite = np.isfinite(d_np)
    assert (finite == np.isfinite(d_jx)).all()
    assert np.allclose(d_np[finite], d_jx[finite], rtol=1e-5)


def test_dense_reference_run_matches_kernel(vgg_profile, paper_network):
    """run_dense (the legacy dense-tensor sweep kept behind solver='scan')
    and the two-stage kernel return identical (dist, path) per threshold."""
    from repro.core.shortest_path import _LayeredDP
    g = build_graph(vgg_profile, paper_network, 16)
    dp = _LayeredDP(g, 7)
    for t in list(dp.all_betas()[::7]) + [np.inf]:
        d1, p1 = dp.run(float(t))
        d2, p2 = dp.run_dense(float(t))
        assert (d1 == d2) or (math.isinf(d1) and math.isinf(d2))
        assert p1 == p2
