"""Adaptive robustness (ISSUE 10): the online drift-rate estimator and
AdaptiveCadence replan policy, the successive-halving policy tuner, tail-sized
admission budgets (``DegradedTail``), the preview-planner LRU bound, and the
``mem_pressure`` fuzz family's serialization round-trip."""

import math

import numpy as np
import pytest

from repro import obs
from repro.core.bcd import bcd_solve
from repro.core.cost_model import (DegradedTail, SimMakespan,
                                   budget_feasible, node_budget_windows)
from repro.ft import (AdaptiveCadence, Coordinator, DriftEstimator,
                      Hysteresis, RateChange, Resync, Straggler,
                      clear_tune_cache, default_tuning_grid,
                      network_signature, resolve_replan_policy,
                      tune_policies)
from repro.ft.adaptive import _signed_net_deviations
from repro.sim import fuzz as F
from repro.sim.fuzz import FuzzConfig, fuzz_event_stream, fuzz_scenario
from repro.sim.policies import MemoryBudgeted
from repro.sim.scenario import NetworkScenario, sampled_network
from repro.sim.validate import random_instance


# ---------------------------------------------------------------------------
# DriftEstimator
# ---------------------------------------------------------------------------

def test_estimator_learns_a_ramp():
    est = DriftEstimator(halflife=2.0, z=2.0)
    for t in range(12):
        est.observe(0.3 * t, float(t))
    assert est.rate == pytest.approx(0.3, rel=0.05)


def test_estimator_detects_negative_drift():
    # degradations drift the level DOWN; the gate must be two-sided
    est = DriftEstimator(halflife=2.0, z=2.0)
    for t in range(12):
        est.observe(-0.3 * t, float(t))
    assert est.rate == pytest.approx(0.3, rel=0.05)


def test_estimator_rejects_flapping_as_noise():
    est = DriftEstimator(halflife=2.0, z=2.0)
    for t in range(40):
        est.observe(0.5 * (t % 2), 0.25 * t)
    assert est.rate == 0.0


def test_estimator_rebase_keeps_statistics():
    est = DriftEstimator(halflife=2.0, z=2.0)
    for t in range(12):
        est.observe(0.3 * t, float(t))
    r = est.rate
    est.rebase()                      # new level reference (post-replan)...
    assert est.rate == pytest.approx(r)   # ...but the learned rate survives
    # the first post-rebase sample re-arms instead of reading a level jump
    est.observe(100.0, 12.0)
    assert est.rate == pytest.approx(r)
    est.reset()
    assert est.rate == 0.0


def test_estimator_ignores_nonfinite_levels():
    est = DriftEstimator()
    est.observe(0.0, 0.0)
    est.observe(-math.inf, 1.0)       # NodeFailure deviation: not a rate
    est.observe(0.1, 2.0)
    assert math.isfinite(est.rate)


# ---------------------------------------------------------------------------
# AdaptiveCadence
# ---------------------------------------------------------------------------

def _coord(seed=3):
    prof, net, _sol, _b, B = random_instance(seed)
    return Coordinator(prof, net, B), net


def test_cadence_follows_square_root_rule():
    p = AdaptiveCadence(solve_cost=0.05, staleness_weight=1.0)
    assert p.cadence == math.inf      # no drift yet: ride out
    est = DriftEstimator(halflife=2.0, z=2.0)
    for t in range(12):
        est.observe(0.2 * t, float(t))
    p.estimator = est
    assert p.cadence == pytest.approx(math.sqrt(2 * 0.05 / est.rate),
                                      rel=1e-9)
    clamped = AdaptiveCadence(solve_cost=0.05, min_cadence=2.0,
                              max_cadence=3.0)
    clamped.estimator = est
    assert clamped.cadence == 2.0


def test_adaptive_replans_on_failure_and_rides_out_flaps():
    from repro.ft.coordinator import NodeFailure
    c, net = _coord()
    p = AdaptiveCadence()
    assert p.decide(NodeFailure(server=1), 0.5, c).replan
    # a flap pair cancels in the cumulative coordinate: no replan, ever
    assert not p.decide(RateChange(0, 1, 0.25), 1.0, c).replan
    assert not p.decide(RateChange(0, 1, 4.0), 1.1, c).replan
    assert p.cadence == math.inf


def test_adaptive_cadence_fires_under_sustained_resync_drift():
    c, net = _coord()
    p = AdaptiveCadence(solve_cost=0.01, halflife=1.0, z=1.0)
    fired = []
    scen = NetworkScenario()          # identity; we degrade by hand below
    for k in range(1, 30):
        t = 0.1 * k
        nodes = [n.__class__(**{**n.__dict__, "f": n.f * math.exp(-0.4 * t)})
                 for n in net.nodes]
        import dataclasses
        snap = dataclasses.replace(net, nodes=nodes)
        d = p.decide(Resync(snap), t, c)
        if d.replan:
            fired.append(t)
            # emulate the harness: adopted replan -> policy observes it
            from repro.ft.coordinator import ReplanOutcome
            p.observe(ReplanOutcome(event=Resync(snap), old_latency=1.0,
                                    new_plan=c.plan, action="replan",
                                    remapped_stages=False), t)
    assert fired, "sustained capacity decay must eventually trigger replans"


def test_step_guard_is_opt_in():
    c, _net = _coord()
    # default: no guard — a single severe step is left to the estimator
    assert AdaptiveCadence()._guard is None
    p = AdaptiveCadence(step_threshold=0.25, step_cooldown=0.0)
    assert isinstance(p._guard, Hysteresis)
    d = p.decide(Straggler(1, 8.0), 1.0, c)
    assert d.replan and "step guard" in d.reason
    assert "step_threshold" in repr(p)


def test_resolve_replan_policy_knows_adaptive():
    assert isinstance(resolve_replan_policy("adaptive"), AdaptiveCadence)
    with pytest.raises(ValueError, match="adaptive"):
        resolve_replan_policy("nope")


def test_signed_net_deviations_roundtrip():
    _c, net = _coord()
    assert all(v == 0.0 for v in _signed_net_deviations(net, net).values())
    import dataclasses
    nodes = list(net.nodes)
    nodes[1] = dataclasses.replace(nodes[1], f=nodes[1].f * 2.0)
    up = dataclasses.replace(net, nodes=nodes)
    devs = _signed_net_deviations(net, up)
    assert devs[("node", 1)] == pytest.approx(math.log(2.0))
    # degraded() renumbers: shapes differ -> no comparable coordinate
    assert _signed_net_deviations(net, net.degraded([1])) == {}


# ---------------------------------------------------------------------------
# network_signature + tune_policies
# ---------------------------------------------------------------------------

def test_network_signature_discriminates():
    from repro.core import make_edge_network
    a = make_edge_network(num_servers=2, seed=0)
    b = make_edge_network(num_servers=2, seed=0)
    c = make_edge_network(num_servers=2, seed=1)
    assert network_signature(a) == network_signature(b)
    assert network_signature(a) != network_signature(c)


def _tune_setup():
    prof, net, _sol, _b, B = random_instance(3)
    streams = [fuzz_event_stream(np.random.default_rng(s), net, horizon=4.0,
                                 max_events=4, allow_failure=False,
                                 flap_fraction=0.75)
               for s in range(300, 306)]
    return prof, net, B, streams


def test_tune_policies_deterministic_and_cached():
    prof, net, B, streams = _tune_setup()
    grid = default_tuning_grid(solve_cost=0.15)
    assert "rate_limited+hyst(0.25,cd=0.3)" in grid and len(grid) == 10
    clear_tune_cache()
    with obs.enabled_scope():
        obs.reset()
        r1 = tune_policies(prof, net, B, streams, configs=grid,
                           min_streams=2, solve_downtime=0.15)
        assert not r1.from_cache
        assert r1.best in grid
        assert r1.signature == network_signature(net)
        # rounds consume monotonically more of the corpus, never more than n
        consumed = [n for _alive, n in r1.rounds]
        assert consumed == sorted(consumed) and consumed[-1] <= len(streams)
        assert obs.counter("ft.tune.rounds") == len(r1.rounds)
        # identical call: served from the per-signature cache
        r2 = tune_policies(prof, net, B, streams, configs=grid,
                           min_streams=2, solve_downtime=0.15)
        assert r2.from_cache and r2.best == r1.best
        assert obs.counter("ft.tune.cache_hits") == 1
    clear_tune_cache()
    r3 = tune_policies(prof, net, B, streams, configs=grid,
                       min_streams=2, solve_downtime=0.15, cache=False)
    assert r3.best == r1.best and r3.score == pytest.approx(r1.score)
    # leaderboard rows are (name, score, n_streams) with full-corpus winners
    names = [row[0] for row in r3.leaderboard]
    assert r3.best in names and len(names) == len(grid)
    d = r3.row()
    assert d["best"] == r3.best and d["signature"] == r3.signature


def test_tune_policies_single_config_and_validation():
    prof, net, B, streams = _tune_setup()
    only = {"hand": lambda: Hysteresis(0.25, cooldown=0.3)}
    res = tune_policies(prof, net, B, streams[:3], configs=only,
                        min_streams=2, cache=False)
    assert res.best == "hand"
    with pytest.raises(ValueError):
        tune_policies(prof, net, B, [], configs=only, cache=False)
    with pytest.raises(ValueError):
        tune_policies(prof, net, B, streams, configs=only, eta=1,
                      cache=False)


def test_tune_one_se_rule_prefers_parsimony():
    """Two configs that act identically on the corpus (statistically tied
    by construction) must rank by replans-per-stream: the eager clone that
    replans on everything cannot displace the quiet one."""
    prof, net, B, streams = _tune_setup()
    from repro.ft import Eager, RideOut
    res = tune_policies(prof, net, B, streams,
                        configs={"eager": Eager, "quiet": RideOut},
                        min_streams=2, solve_downtime=0.0, cache=False)
    # zero downtime: both see identical makespans -> tied -> parsimony
    assert res.best == "quiet"


# ---------------------------------------------------------------------------
# DegradedTail admission budgets
# ---------------------------------------------------------------------------

def _mem_scenarios(net, n, seed=0):
    rng = np.random.default_rng(seed)
    cfg = FuzzConfig(families=("mem_pressure",), min_events=1, max_events=2)
    return [fuzz_scenario(rng, net, cfg) for _ in range(n)]


def test_degraded_tail_arithmetic():
    prof, net, _sol, _b, B = random_instance(3)
    scens = _mem_scenarios(net, 8)
    alpha = 1.0 - 1.0 / len(scens) + 1e-9          # tail = worst scenario
    tail = DegradedTail.from_scenarios(net, scens, alpha=alpha)
    for i, node in enumerate(net.nodes):
        worst = min(min(s.mem_mult[i].values) if i in s.mem_mult else 1.0
                    for s in scens)
        assert tail.node_mem(net, i) == pytest.approx(node.mem * worst)
        assert tail.node_mem(net, i) <= node.mem + 1e-9
    # None entries and short tuples fall back to the nominal budget
    assert DegradedTail(mem=(None,)).node_mem(net, 0) == net.nodes[0].mem
    with pytest.raises(ValueError):
        DegradedTail.from_scenarios(net, [], alpha=0.5)
    with pytest.raises(ValueError):
        DegradedTail.from_scenarios(net, scens, alpha=1.0)


def test_tail_windows_never_exceed_nominal():
    prof, net, sol, b, B = random_instance(3)
    scens = _mem_scenarios(net, 8)
    tail = DegradedTail.from_scenarios(net, scens, alpha=0.8)
    nominal = node_budget_windows(prof, net, sol, b)
    tightened = node_budget_windows(prof, net, sol, b, tail=tail)
    assert len(tightened) == len(nominal)
    for tw, nw in zip(tightened, nominal):
        if nw is None:                    # unbounded: no activation bytes
            assert tw is None
        else:
            assert tw <= nw
    if budget_feasible(prof, net, sol, b, tail=tail):
        assert budget_feasible(prof, net, sol, b)
    # the admission policy and the planning cost model accept the same seam
    MemoryBudgeted(tail=tail)
    SimMakespan(policy="memory", tail=tail)


# ---------------------------------------------------------------------------
# Coordinator preview-planner LRU (the 10k-flap regression)
# ---------------------------------------------------------------------------

def test_preview_memo_is_bounded_under_flap_storm():
    prof, net, _sol, _b, B = random_instance(3)
    with obs.enabled_scope():
        obs.reset()
        c = Coordinator(prof, net, B, preview_cache_size=8)
        sol = c.plan.solution
        for i in range(10_000):        # 5k flaps: distinct (factor, 1/factor)
            f = 0.3 + (i % 4_999) * 1e-4
            c.preview_cached(sol, RateChange(0, 1, f if i % 2 == 0
                                             else 1.0 / f))
        assert len(c._preview_planners) <= 8
        assert obs.counter("ft.preview_evictions") > 0
    with pytest.raises(ValueError):
        Coordinator(prof, net, B, preview_cache_size=0)


def test_preview_memo_lru_keeps_hot_entries():
    prof, net, _sol, _b, B = random_instance(3)
    # each miss memoizes two entries (per-network planner + per-event key),
    # so size 3 holds exactly one hot event across a stream of cold misses
    c = Coordinator(prof, net, B, preview_cache_size=3)
    sol = c.plan.solution
    hot = RateChange(0, 1, 0.5)
    c.preview_cached(sol, hot)
    hot_key = (id(c.net), ("RC", 0, 1, 0.5))
    for f in (0.6, 0.7, 0.8):
        c.preview_cached(sol, hot)     # touch: most-recently-used again
        c.preview_cached(sol, RateChange(0, 1, f))
        assert hot_key in c._preview_planners
    assert len(c._preview_planners) <= 3
    assert (id(c.net), ("RC", 0, 1, 0.6)) not in c._preview_planners


# ---------------------------------------------------------------------------
# mem_pressure fuzz family: scenario + serialization round-trip
# ---------------------------------------------------------------------------

def test_mem_pressure_scenario_and_roundtrip(tmp_path):
    prof, net, _sol, _b, B = random_instance(3)
    rng = np.random.default_rng(0)
    cfg = FuzzConfig(families=("mem_pressure",), min_events=1, max_events=2)
    scen = fuzz_scenario(rng, net, cfg)
    assert scen.mem_mult                              # family fired
    for n, tr in scen.mem_mult.items():
        assert min(tr.values) >= 0.25 - 1e-12         # documented floor
        assert max(tr.values) <= 1.0 + 1e-12
        # mem_trace is the absolute byte trace: budget x multiplier
        trace = scen.mem_trace(net, n)
        assert trace.values == pytest.approx(
            tuple(net.nodes[n].mem * v for v in tr.values))
    # the multiplier scales available memory in the sampled network
    t_mid = sum(next(iter(scen.mem_mult.values())).times[:2]) / 2.0 \
        if len(next(iter(scen.mem_mult.values())).times) > 1 else 0.0
    snap = sampled_network(net, scen, t_mid)
    for i, node in enumerate(net.nodes):
        assert snap.nodes[i].mem <= node.mem + 1e-9
    # byte-stable save/load through the corpus format
    case = F.fuzz_case(7)
    case = type(case)(**{**case.__dict__, "scenario": scen})
    path = F.save_case(case, str(tmp_path), "mem_case")
    loaded = F.load_case(path)
    assert loaded.scenario.mem_mult.keys() == scen.mem_mult.keys()
    for n in scen.mem_mult:
        assert loaded.scenario.mem_mult[n].times == scen.mem_mult[n].times
        assert loaded.scenario.mem_mult[n].values == scen.mem_mult[n].values


def test_fuzz_scenario_weighted_untilted_matches_nominal():
    prof, net, _sol, _b, B = random_instance(3)
    cfg = FuzzConfig(min_events=1, max_events=3)
    s1 = fuzz_scenario(np.random.default_rng(11), net, cfg)
    s2, w = F.fuzz_scenario_weighted(np.random.default_rng(11), net, cfg)
    assert w == pytest.approx(1.0)
    assert s2.node_mult.keys() == s1.node_mult.keys()
    assert s2.link_mult.keys() == s1.link_mult.keys()
    for k in s1.node_mult:
        assert s2.node_mult[k].times == s1.node_mult[k].times
        assert s2.node_mult[k].values == s1.node_mult[k].values
