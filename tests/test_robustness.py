"""CVaR robustness scoring, the RobustMakespan cost-model seam, blocked-time
attribution, and coordinator replanning under fuzzed event streams (with the
checkpoint-restore charge and the ride-out outcome guarantee)."""

import math

import numpy as np
import pytest

from repro.core.bcd import bcd_solve
from repro.core.cost_model import ClosedForm
from repro.ft.coordinator import Coordinator, NodeFailure, RateChange
from repro.sim import fuzz as F
from repro.sim.engine import simulate_plan, simulate_with_replanning
from repro.sim.robustness import (RobustMakespan, cvar, scenario_distribution,
                                  score_plan, score_plans)
from repro.sim.scenario import NetworkScenario, ReplanTrigger
from repro.sim.validate import random_instance


# ---------------------------------------------------------------------------
# CVaR arithmetic
# ---------------------------------------------------------------------------

def test_cvar_definition():
    xs = [1.0, 2.0, 3.0, 10.0]
    assert cvar(xs, alpha=0.75) == 10.0          # worst 1 of 4
    assert cvar(xs, alpha=0.5) == 6.5            # worst 2 of 4
    assert cvar(xs, alpha=0.0) == pytest.approx(4.0)   # the plain mean
    assert cvar([5.0], alpha=0.95) == 5.0
    with pytest.raises(ValueError):
        cvar(xs, alpha=1.0)
    with pytest.raises(ValueError):
        cvar([], alpha=0.5)


def test_cvar_dominates_mean_and_is_monotone_in_alpha():
    rng = np.random.default_rng(0)
    xs = rng.lognormal(size=100)
    vals = [cvar(xs, a) for a in (0.0, 0.5, 0.9, 0.99)]
    assert vals[0] == pytest.approx(float(np.mean(xs)))
    assert all(a <= b + 1e-12 for a, b in zip(vals, vals[1:]))
    assert vals[-1] <= float(np.max(xs)) + 1e-12


# ---------------------------------------------------------------------------
# Scoring: single plan, batched plans, attribution
# ---------------------------------------------------------------------------

def _instance(seed=5):
    return random_instance(seed)


def test_score_plan_matches_direct_simulation():
    prof, net, sol, b, B = _instance()
    scens = scenario_distribution(net, 5, seed=3, profile=prof, sol=sol, b=b)
    rep = score_plan(prof, net, sol, b, B=B, scenarios=scens)
    for ms, scen in zip(rep.makespans, scens):
        direct = simulate_plan(prof, net, sol, b, B=B, scenario=scen,
                               engine="auto")
        assert ms == direct.L_t
    nominal = simulate_plan(prof, net, sol, b, B=B, engine="auto")
    assert rep.nominal == nominal.L_t
    assert rep.mean <= rep.p95 + 1e-12
    assert rep.p95 <= rep.cvar + 1e-9 or math.isclose(rep.p95, rep.cvar)
    assert rep.cvar <= rep.worst + 1e-12
    assert rep.tail_inflation >= 1.0 - 1e-9     # failures never speed it up


def test_score_plans_batched_equals_looped():
    prof, net, sol, b, B = _instance(7)
    scens = scenario_distribution(net, 4, seed=1, profile=prof, sol=sol, b=b)
    cands = [(sol, b), (sol, max(1, b // 2))]
    batched = score_plans(prof, net, cands, B=B, scenarios=scens)
    for (s, bb), rep in zip(cands, batched):
        single = score_plan(prof, net, s, bb, B=B, scenarios=scens,
                            attribution=False)
        assert single.makespans == rep.makespans
        assert single.nominal == rep.nominal


def test_blocked_attribution_names_the_outaged_link():
    """An outage on the plan's first hop must show up as blocked time
    attributed to that link's transfer resources."""
    prof, net, sol, b, B = _instance(5)
    a, c = sol.placement[0], sol.placement[1]
    nominal = simulate_plan(prof, net, sol, b, B=B)
    width = max(nominal.L_t, 1e-3)
    scen = NetworkScenario().with_outage(a, c, 0.0, 0.5 * width,
                                         both_directions=True)
    rep = score_plan(prof, net, sol, b, B=B, scenarios=[scen])
    top = rep.top_blocked()
    assert top, "outage produced no blocked attribution"
    assert any(res[0] in ("fwd", "bwd") and (res[1], res[2]) in
               ((a, c), (c, a)) for res, _t in top), top
    # the UtilizationReport rollups expose the same accounting
    from repro.obs import resource_traces
    from repro.sim.engine import build_visit_table
    run = simulate_plan(prof, net, sol, b, B=B, scenario=scen)
    table = build_visit_table(prof, net, sol, b)
    util = run.utilization(traces=resource_traces(net, scen,
                                                  set(table.resources)))
    assert util.blocked_fraction_total > 0.0
    by_res = util.blocked_by_resource()
    assert by_res and all(t > 0 for t in by_res.values())
    assert list(by_res.values()) == sorted(by_res.values(), reverse=True)


# ---------------------------------------------------------------------------
# RobustMakespan through the CostModel seam
# ---------------------------------------------------------------------------

def test_robust_makespan_evaluate_matches_many():
    prof, net, sol, b, B = _instance(9)
    scens = scenario_distribution(net, 4, seed=2, profile=prof, sol=sol, b=b)
    cm = RobustMakespan(scenarios=scens)
    one = cm.evaluate(prof, net, sol, b, B)
    many = cm.evaluate_many(prof, net, [(sol, b), (sol, b)], B)
    # a two-plan batch may group same-structure trace runs through the
    # stacked fixpoint, which reassociates float reductions: ulp-level only
    assert many[0] == many[1]
    assert one == pytest.approx(many[0], rel=1e-12)
    assert cm.evaluate_many(prof, net, [(sol, 0)], B) == [math.inf]


def test_risk_aversion_interpolates_mean_to_cvar():
    prof, net, sol, b, B = _instance(9)
    scens = scenario_distribution(net, 6, seed=2, profile=prof, sol=sol, b=b)
    rep = score_plan(prof, net, sol, b, B=B, scenarios=scens,
                     attribution=False)
    lo = RobustMakespan(scenarios=scens, risk_aversion=0.0)
    hi = RobustMakespan(scenarios=scens, risk_aversion=1.0)
    mid = RobustMakespan(scenarios=scens, risk_aversion=0.5)
    v_lo = lo.evaluate(prof, net, sol, b, B)
    v_hi = hi.evaluate(prof, net, sol, b, B)
    assert v_lo == pytest.approx(rep.mean, rel=1e-12)
    assert v_hi == pytest.approx(rep.cvar, rel=1e-12)
    assert mid.evaluate(prof, net, sol, b, B) == \
        pytest.approx(0.5 * (v_lo + v_hi), rel=1e-12)
    with pytest.raises(ValueError):
        RobustMakespan(risk_aversion=1.5)


def test_bcd_solves_under_robust_makespan():
    prof, net, _sol, _b, B = _instance(5)
    cm = RobustMakespan(n_scenarios=4, seed=1)
    plan = bcd_solve(prof, net, B, cost_model=cm)
    assert plan.feasible
    assert plan.cost_model == "robust_makespan"
    assert math.isfinite(plan.objective)
    # the reported objective is reproducible against the cached distribution
    again = cm.evaluate(prof, net, plan.solution, plan.b, B)
    assert again == pytest.approx(plan.objective, rel=1e-12)


def test_lazy_distribution_cached_per_network():
    prof, net, sol, b, B = _instance(3)
    cm = RobustMakespan(n_scenarios=3, seed=0)
    d1 = cm.distribution(prof, net, sol, b, B)
    d2 = cm.distribution(prof, net, sol, b, B)
    assert d1 is d2
    prof2, net2, sol2, b2, _ = _instance(4)
    d3 = cm.distribution(prof2, net2, sol2, b2, B)
    assert d3 is not d1


# ---------------------------------------------------------------------------
# Coordinator under fuzzed event streams: restore charge + ride-out outcome
# ---------------------------------------------------------------------------

def test_node_failure_charges_restore_cost_into_downtime():
    for seed in range(30):
        prof, net, _sol, _b, B = random_instance(seed)
        if len(net.nodes) >= 4:
            break
    coord = Coordinator(prof, net, B, restore_cost=0.5)
    horizon = max(coord.plan.L_t, 1e-6)
    trig = ReplanTrigger(0.3 * horizon, NodeFailure(1))
    rep = simulate_with_replanning(prof, net, B, (trig,), coordinator=coord)
    outs = [s.outcome for s in rep.segments if s.outcome is not None]
    assert outs and outs[0].restore_seconds == 0.5
    assert outs[0].log_record()["restore_seconds"] == 0.5
    # the resumed segment starts only after the restore charge
    resumed = [s for s in rep.segments if s.trigger is None]
    if resumed and math.isfinite(rep.makespan):
        assert resumed[0].report.t_start >= trig.time + 0.5 - 1e-12


def test_restore_cost_callable_sources_checkpoint_metadata(tmp_path):
    jax = pytest.importorskip("jax")
    from repro.checkpoint import estimate_restore_seconds, save_checkpoint
    save_checkpoint(str(tmp_path), 1,
                    {"w": np.ones((32, 32), np.float32)})
    for seed in range(30):
        prof, net, _sol, _b, B = random_instance(seed)
        if len(net.nodes) >= 4:
            break
    coord = Coordinator(
        prof, net, B,
        restore_cost=lambda: estimate_restore_seconds(str(tmp_path)))
    out = coord.apply(NodeFailure(1))
    assert out.restore_seconds > 0.0
    assert out.restore_seconds == estimate_restore_seconds(str(tmp_path))
    out2 = coord.apply(RateChange(0, 1, 0.5))
    assert out2.restore_seconds == 0.0       # only failures pay a restore


def _ride_out_latency(coord, prof, old_sol, old_b, B):
    """Closed-form latency of keeping the pre-event plan on the mutated
    network (inf when it no longer fits)."""
    cm = ClosedForm()
    if old_sol is None:
        return math.inf
    try:
        if not cm.memory_feasible(prof, coord.net, old_sol, old_b):
            return math.inf
        return cm.evaluate(prof, coord.net, old_sol, old_b, B)
    except Exception:
        return math.inf


def test_replanned_latency_never_worse_than_riding_out():
    """The ISSUE's outcome assertion, across fuzzed event streams: after
    every event the adopted plan's objective is <= the old plan carried
    onto the mutated network (restore/remap downtime is charged separately
    by the driver, not in the objective)."""
    checked = 0
    for seed in range(10):
        prof, net, _sol, _b, B = random_instance(seed)
        rng = np.random.default_rng(seed)
        coord = Coordinator(prof, net, B)
        horizon = max(coord.plan.L_t, 1e-6)
        trigs = F.fuzz_event_stream(rng, net, horizon=horizon,
                                    allow_failure=len(net.nodes) > 3)
        for trig in trigs:
            old_sol, old_b = coord.plan.solution, coord.plan.b
            if isinstance(trig.event, NodeFailure):
                old_sol = Coordinator._remap_across_failure(
                    old_sol, trig.event.server)
            out = coord.apply(trig.event, sim_time=trig.time)
            ride = _ride_out_latency(coord, prof, old_sol, old_b, B)
            assert out.new_latency <= ride * (1 + 1e-9) + 1e-12, \
                (seed, trig, out.new_latency, ride)
            assert out.action in ("replan", "microbatch")
            checked += 1
    assert checked >= 10


def test_remap_across_failure_index_arithmetic():
    from repro.core.latency import SplitSolution
    sol = SplitSolution(cuts=(2, 4, 6), placement=(0, 1, 3))
    remapped = Coordinator._remap_across_failure(sol, 2)
    assert remapped.placement == (0, 1, 2)   # 3 shifts down past dropped 2
    assert remapped.cuts == sol.cuts
    assert Coordinator._remap_across_failure(sol, 1) is None  # hosted a stage


# ---------------------------------------------------------------------------
# Importance sampling: weighted CVaR + tilted scenario distributions
# ---------------------------------------------------------------------------

def test_weighted_cvar_reduces_to_fractional_tail():
    from repro.sim.robustness import cvar as _cvar
    xs = [1.0, 2.0, 3.0, 10.0]
    # tail mass = 0.5 * 4 = 2 samples: (10 + 3) / 2 — matches the ceil path
    assert _cvar(xs, 0.5, [1, 1, 1, 1]) == pytest.approx(6.5)
    # doubling one weight shifts the tail boundary fractionally:
    # tail = 0.5 * 5 = 2.5 -> 10 (take 1) + 3 (take 1) + 2 (take 0.5)
    assert _cvar(xs, 0.5, [1, 2.0, 1, 1]) == pytest.approx(14.0 / 2.5)
    # weights concentrated on the worst value: cvar -> that value
    assert _cvar(xs, 0.5, [0, 0, 0, 1.0]) == pytest.approx(10.0)
    with pytest.raises(ValueError):
        _cvar(xs, 0.5, [1, 1])                      # shape mismatch
    with pytest.raises(ValueError):
        _cvar(xs, 0.5, [0, 0, 0, 0])                # zero total weight
    with pytest.raises(ValueError):
        _cvar(xs, 0.5, [1, -1, 1, 1])               # negative weight


def test_weighted_cvar_monotone_and_bounded():
    rng = np.random.default_rng(1)
    xs = rng.lognormal(size=200)
    w = rng.uniform(0.1, 3.0, size=200)
    vals = [cvar(xs, a, w) for a in (0.0, 0.5, 0.9, 0.99)]
    assert vals[0] == pytest.approx(float(np.average(xs, weights=w)))
    assert all(a <= b + 1e-12 for a, b in zip(vals, vals[1:]))
    assert vals[-1] <= float(np.max(xs)) + 1e-12


def test_importance_distribution_tilts_event_counts():
    from repro.sim.robustness import importance_scenario_distribution
    prof, net, sol, b, B = _instance()
    cfg = F.FuzzConfig(min_events=1, max_events=3)
    scens, w = importance_scenario_distribution(
        net, 40, seed=0, tilt=4.0, config=cfg, profile=prof, sol=sol, b=b)
    assert len(scens) == 40 and len(w) == 40
    # weights take the K discrete likelihood-ratio values, all positive
    assert all(x > 0 for x in w)
    assert len(set(np.round(w, 12))) <= 3
    # the tilt over-samples heavy scenarios: small weights (high q) dominate
    assert float(np.mean(w)) < 1.0
    # tilt=1 recovers the uniform sampler: every weight is exactly 1
    _, w1 = importance_scenario_distribution(net, 10, seed=0, tilt=1.0,
                                             config=cfg, profile=prof,
                                             sol=sol, b=b)
    assert all(x == pytest.approx(1.0) for x in w1)


def test_importance_sampled_cvar_matches_uniform_reference():
    """The acceptance regression: IS CVaR estimates (n=16, tilted toward
    compound failures) agree with a LARGE uniform reference sample within
    the reference's own sampling error band.  Both sides use the weighted
    (fractional-tail) estimator so the convention matches."""
    from repro.sim.robustness import importance_scenario_distribution
    prof, net, sol, b, B = _instance()
    cfg = F.FuzzConfig(min_events=1, max_events=3)
    alpha = 0.75

    def makespans(scens):
        return [simulate_plan(prof, net, sol, b, B=B, scenario=s,
                              engine="auto").L_t for s in scens]

    ref_scens = scenario_distribution(net, 160, seed=100, config=cfg,
                                      profile=prof, sol=sol, b=b)
    ref_ms = makespans(ref_scens)
    ref_cvar = cvar(ref_ms, alpha, np.ones(len(ref_ms)))

    # spread across independent seeds, the small-n IS estimator must land
    # around the big-sample reference (unbiasedness), each estimate inside
    # a generous relative band
    est = []
    for seed in range(5):
        scens, w = importance_scenario_distribution(
            net, 16, seed=seed, tilt=3.0, config=cfg, profile=prof,
            sol=sol, b=b)
        est.append(cvar(makespans(scens), alpha, w))
        assert est[-1] == pytest.approx(ref_cvar, rel=0.35)
    assert float(np.mean(est)) == pytest.approx(ref_cvar, rel=0.15)


def test_score_plan_accepts_weights():
    from repro.sim.robustness import importance_scenario_distribution
    prof, net, sol, b, B = _instance()
    scens, w = importance_scenario_distribution(net, 8, seed=2, profile=prof,
                                                sol=sol, b=b)
    rep = score_plan(prof, net, sol, b, B=B, scenarios=scens, weights=w,
                     alpha=0.75, attribution=False)
    assert rep.weights == tuple(w)
    assert rep.cvar == pytest.approx(
        cvar(rep.makespans, 0.75, np.asarray(w)))
    assert rep.mean == pytest.approx(
        float(np.average(rep.makespans, weights=w)))
    assert rep.p95 >= rep.mean - 1e-12 or rep.p95 <= max(rep.makespans)


# ---------------------------------------------------------------------------
# Weighted-CVaR estimator properties (ISSUE 10 satellite): hypothesis suite
# + a deterministic twin that runs without the optional dep
# ---------------------------------------------------------------------------

def _check_weighted_cvar_properties(xs, w, alpha):
    v = cvar(xs, alpha, w)
    # bounded by the weighted mean below and the max above
    assert float(np.average(xs, weights=w)) <= v + 1e-9
    assert v <= float(np.max(xs)) + 1e-9
    # scale invariance in the weights (only relative mass matters)
    assert cvar(xs, alpha, 3.7 * np.asarray(w)) == pytest.approx(v)
    # monotone in alpha
    assert cvar(xs, min(alpha + 0.1, 0.999), w) >= v - 1e-9
    # permutation invariance
    order = np.argsort(xs)
    assert cvar(np.asarray(xs)[order], alpha,
                np.asarray(w)[order]) == pytest.approx(v)
    # uniform weights with an integral tail match the unweighted ceil path
    n = len(xs)
    k = (1.0 - alpha) * n
    if abs(k - round(k)) < 1e-9 and round(k) >= 1:
        assert cvar(xs, alpha, np.ones(n)) == pytest.approx(cvar(xs, alpha))


def test_weighted_cvar_properties_seeded_sweep():
    """Deterministic twin of the hypothesis property (runs everywhere)."""
    for seed in (0, 3, 11):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(4, 60))
        xs = rng.lognormal(size=n)
        w = rng.uniform(0.05, 4.0, size=n)
        for alpha in (0.0, 0.25, 0.5, 0.75):
            _check_weighted_cvar_properties(xs, w, alpha)


def test_weighted_cvar_properties_hypothesis():
    pytest.importorskip("hypothesis")  # optional dev dep
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=80, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=10_000),
           alpha=st.floats(min_value=0.0, max_value=0.95))
    def prop(seed, alpha):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(2, 80))
        xs = rng.lognormal(size=n)
        w = rng.uniform(0.01, 5.0, size=n)
        _check_weighted_cvar_properties(xs, w, alpha)

    prop()


def test_kind_and_severity_tilted_cvar_matches_uniform_reference():
    """The ISSUE 10 regression: joint kind x severity importance sampling
    stays unbiased — small-n tilted estimates land around a large uniform
    reference, same protocol as the count-tilt regression above."""
    from repro.sim.robustness import importance_scenario_distribution
    prof, net, sol, b, B = _instance()
    cfg = F.FuzzConfig(min_events=1, max_events=3)
    alpha = 0.75

    def makespans(scens):
        return [simulate_plan(prof, net, sol, b, B=B, scenario=s,
                              engine="auto").L_t for s in scens]

    ref_scens = scenario_distribution(net, 160, seed=100, config=cfg,
                                      profile=prof, sol=sol, b=b)
    ref_ms = makespans(ref_scens)
    ref_cvar = cvar(ref_ms, alpha, np.ones(len(ref_ms)))

    est = []
    for seed in range(5):
        scens, w = importance_scenario_distribution(
            net, 16, seed=seed, tilt=2.0,
            kind_tilt={"outage": 3.0, "degradation": 2.0}, severity_tilt=2.0,
            config=cfg, profile=prof, sol=sol, b=b)
        assert all(x > 0 for x in w)
        est.append(cvar(makespans(scens), alpha, w))
        assert est[-1] == pytest.approx(ref_cvar, rel=0.35)
    assert float(np.mean(est)) == pytest.approx(ref_cvar, rel=0.15)
