"""Unit + property tests for the Eq. (1)-(14) latency model."""

import math

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep: skip module, not error
from hypothesis import given, settings, strategies as st

from repro.core import (EdgeNetwork, Node, SplitSolution, breakdown,
                        client_shares, fill_latency, memory_feasible,
                        no_pipeline_latency, num_fills, pipeline_interval,
                        total_latency, uniform_profile, validate_solution,
                        vgg16_profile, make_edge_network, shannon_rate)
from conftest import small_instance


def _tiny_net():
    """Deterministic 2-server network for hand-computed checks."""
    nodes = [
        Node("clients", f=1e9, kappa=1.0, mem=1e12, t0=0.0, t1=0.0, b_th=0,
             is_client=True),
        Node("s1", f=2e9, kappa=1.0, mem=1e12, t0=0.0, t1=0.0, b_th=0),
        Node("s2", f=4e9, kappa=1.0, mem=1e12, t0=0.0, t1=0.0, b_th=0),
    ]
    rate = np.array([[0, 1e6, 1e6], [1e6, 0, 2e6], [1e6, 2e6, 0.0]])
    return EdgeNetwork(nodes=nodes, rate=rate, num_clients=1)


def test_client_shares_eq1():
    # Eq. (1): floor split, remainder to the last client
    shares = client_shares(10, 4)
    assert list(shares) == [2, 2, 2, 4]
    assert shares.sum() == 10
    assert list(client_shares(8, 4)) == [2, 2, 2, 2]


def test_hand_computed_fill_latency():
    prof = uniform_profile(4, fp=1e6, bp=2e6, act=1e3, param=0.0)
    net = _tiny_net()
    sol = SplitSolution(cuts=(2, 4), placement=(0, 1))
    validate_solution(sol, prof, net)
    b = 8
    # client FP: 8 * 2e6 / 1e9 ; client BP: 8 * 4e6 / 1e9
    # comm fwd: 8 * 1e3 / 1e6 ; comm bwd same
    # server FP: 8 * 2e6 / 2e9 ; BP: 8 * 4e6 / 2e9
    expect = (8 * 2e6 / 1e9 + 8 * 4e6 / 1e9 + 8 * 1e3 / 1e6 * 2
              + 8 * 2e6 / 2e9 + 8 * 4e6 / 2e9)
    assert fill_latency(prof, net, sol, b) == pytest.approx(expect)
    # T_i: max individual component = client BP = 0.032
    assert pipeline_interval(prof, net, sol, b) == pytest.approx(0.032)
    # Eq. 14
    B = 64
    assert total_latency(prof, net, sol, b, B) == pytest.approx(
        expect + math.ceil((B - b) / b) * 0.032)


def test_colocation_sums_in_interval():
    """C9/C13: submodels sharing a node SUM into that node's T_i term."""
    prof = uniform_profile(6, fp=1e6, bp=1e6, act=1e2, param=0.0)
    net = _tiny_net()
    sol = SplitSolution(cuts=(2, 4, 6), placement=(0, 1, 2))
    sol_reuse = SplitSolution(cuts=(1, 2, 4, 6), placement=(0, 1, 2, 1))
    t_plain = pipeline_interval(prof, net, sol, 8)
    bd = breakdown(prof, net, sol_reuse, 8)
    sums = bd.node_fp_sums()
    assert sums[1] == pytest.approx(bd.stage_fp[1] + bd.stage_fp[3])


def test_no_pipeline_is_fill_at_B():
    prof, net = small_instance(0)
    sol = SplitSolution(cuts=(3, 6), placement=(0, 1))
    assert no_pipeline_latency(prof, net, sol, 128) == pytest.approx(
        fill_latency(prof, net, sol, 128))


def test_shannon_rate_monotonic():
    r1 = shannon_rate(10e6, 0.3, 100.0)
    assert r1 > shannon_rate(10e6, 0.3, 200.0)      # farther -> slower
    assert shannon_rate(20e6, 0.3, 100.0) > r1      # more BW -> faster
    assert r1 > 0


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 100), b=st.integers(1, 64))
def test_interval_is_max_of_components(seed, b):
    prof, net = small_instance(seed)
    sol = SplitSolution(cuts=(2, 4, 6), placement=(0, 1, 2))
    bd = breakdown(prof, net, sol, b)
    t = pipeline_interval(prof, net, sol, b)
    comps = (list(bd.node_fp_sums().values())
             + list(bd.node_bp_sums().values())
             + list(bd.pair_fwd_sums().values())
             + list(bd.pair_bwd_sums().values()))
    assert t == pytest.approx(max(comps))
    assert all(t >= c - 1e-12 for c in comps)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 100), b=st.integers(1, 32))
def test_total_latency_bounds(seed, b):
    """T_f <= L_t and L_t <= ceil(B/b) * T_f (pipeline can't be worse than
    fully sequential micro-batches)."""
    prof, net = small_instance(seed)
    sol = SplitSolution(cuts=(3, 6), placement=(0, 2))
    B = 64
    L = total_latency(prof, net, sol, b, B)
    T_f = fill_latency(prof, net, sol, b)
    assert L >= T_f - 1e-12
    assert L <= math.ceil(B / b) * T_f + 1e-9


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 50))
def test_memory_monotone_in_b(seed):
    prof, net = small_instance(seed)
    sol = SplitSolution(cuts=(3, 6), placement=(0, 1))
    feas = [memory_feasible(prof, net, sol, b) for b in (1, 8, 64, 512)]
    # once infeasible, stays infeasible
    for a, c in zip(feas, feas[1:]):
        assert a or not c


def test_validate_rejects_bad_solutions():
    prof, net = small_instance(0)
    with pytest.raises(ValueError):
        validate_solution(SplitSolution((6,), (1,)), prof, net)  # not client
    with pytest.raises(ValueError):
        validate_solution(SplitSolution((4, 2, 6), (0, 1, 2)), prof, net)
    with pytest.raises(ValueError):  # consecutive same node
        validate_solution(SplitSolution((2, 4, 6), (0, 1, 1)), prof, net)
    with pytest.raises(ValueError):  # last cut != I
        validate_solution(SplitSolution((2, 5), (0, 1)), prof, net)


def test_num_fills_eq14():
    assert num_fills(512, 512) == 0
    assert num_fills(512, 20) == math.ceil(492 / 20)
    assert num_fills(512, 256) == 1
