"""JAX planner backend (ISSUE 9) — dtype contract, parity, counters.

Runtime companion to the hypothesis cross-check in tests/test_msp.py
(which skips wholesale when hypothesis is absent): seeded grids here run
unconditionally wherever jax imports.
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro import obs
from repro.core import Planner, build_graph
from repro.core import planner_jax
from repro.core.shortest_path import _LayeredDP
from conftest import same_msp_result as _same_result, small_instance

if not planner_jax.available():            # pragma: no cover
    pytest.skip("jax backend unavailable", allow_module_level=True)


class _x64:
    """Temporarily force the x64 flag; restores the prior value on exit."""

    def __init__(self, enable: bool):
        self.enable = enable

    def __enter__(self):
        self.prev = jax.config.jax_enable_x64
        jax.config.update("jax_enable_x64", self.enable)

    def __exit__(self, *exc):
        jax.config.update("jax_enable_x64", self.prev)


# -- satellite: dtype detection -------------------------------------------


def test_sweep_dtype_tracks_x64_flag():
    with _x64(False):
        assert planner_jax.sweep_dtype() == "float32"
        assert planner_jax.parity_tolerance() > 0.0
    with _x64(True):
        assert planner_jax.sweep_dtype() == "float64"
        assert planner_jax.parity_tolerance() == 0.0


@pytest.mark.parametrize("enable_x64", [False, True])
def test_dist_at_jax_parity_both_modes(vgg_profile, paper_network,
                                       enable_x64):
    """_dist_at_jax honors the documented tolerance contract in both
    dtype modes: bit-exact under x64, rtol ``parity_tolerance()`` under
    the default float32 config."""
    g = build_graph(vgg_profile, paper_network, 16)
    dp = _LayeredDP(g, 7)
    betas = dp.all_betas()
    ts = betas[:: max(1, len(betas) // 24)]
    d_np = dp.dist_at(ts)
    with _x64(enable_x64):
        d_jx = dp.dist_at(ts, backend="jax")
        rtol = planner_jax.parity_tolerance()
    assert d_jx.dtype == np.float64          # host contract: always f64 out
    finite = np.isfinite(d_np)
    assert (finite == np.isfinite(d_jx)).all()
    if enable_x64:
        assert np.array_equal(d_np, d_jx)
    else:
        assert np.allclose(d_np[finite], d_jx[finite], rtol=rtol)


# -- parity: full solve / solve_many through the jitted pipeline ----------


@pytest.mark.parametrize("seed", [0, 1, 2, 7, 11])
def test_solve_backend_jax_matches_numpy(seed):
    prof, net = small_instance(seed, num_layers=5, num_servers=3)
    B = 32
    for b in (4, 13):
        r_np = Planner(prof, net).solve(b, B, solver="batched")
        r_jx = Planner(prof, net).solve(b, B, solver="batched",
                                        backend="jax")
        rtol = planner_jax.parity_tolerance()
        assert r_np.feasible == r_jx.feasible
        if not r_np.feasible:
            continue
        if rtol == 0.0:
            assert _same_result(r_np, r_jx), (r_np, r_jx)
        else:
            assert r_jx.objective == pytest.approx(r_np.objective, rel=rtol)
            assert r_jx.b == r_np.b


@pytest.mark.parametrize("seed", [0, 3, 9])
def test_solve_many_backend_jax_matches_numpy(seed):
    prof, net = small_instance(seed, num_layers=5, num_servers=3)
    B = 32
    bs = list(range(1, B + 1, 5))
    many_np = Planner(prof, net).solve_many(bs, B)
    many_jx = Planner(prof, net).solve_many(bs, B, backend="jax")
    rtol = planner_jax.parity_tolerance()
    assert len(many_np) == len(many_jx)
    for m_np, m_jx in zip(many_np, many_jx):
        assert m_np.feasible == m_jx.feasible
        if not m_np.feasible:
            continue
        if rtol == 0.0:
            assert _same_result(m_np, m_jx), (m_np, m_jx)
        else:
            assert m_jx.objective == pytest.approx(m_np.objective, rel=rtol)
            # the searched split itself must agree even in f32: a wrong
            # placement would show as a >1e-4 objective gap on reprice
            assert m_jx.b == m_np.b


def test_solve_many_backend_jax_bit_exact_under_x64():
    prof, net = small_instance(5, num_layers=6, num_servers=4)
    bs = [2, 7, 16, 31]
    many_np = Planner(prof, net).solve_many(bs, 32)
    with _x64(True):
        many_jx = Planner(prof, net).solve_many(bs, 32, backend="jax")
    for m_np, m_jx in zip(many_np, many_jx):
        assert _same_result(m_np, m_jx), (m_np, m_jx)


# -- counters --------------------------------------------------------------


def test_jax_dispatch_counter_increments():
    prof, net = small_instance(2, num_layers=5, num_servers=3)
    obs.reset()
    with obs.enabled_scope():
        Planner(prof, net).solve_many([4, 8], 32, backend="jax")
    assert obs.counter("planner.jax_dispatches") > 0
    assert obs.counter("planner.pallas_dispatches") == 0
    obs.reset()
