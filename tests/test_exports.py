"""Lazy-export hygiene: every name the top-level ``repro`` package promises
must resolve, and its sim re-export set must mirror ``repro.sim.__all__``
exactly (the ISSUE 2 sync fix — PR 1 had drifted)."""

import importlib

import pytest

import repro


def test_every_top_level_export_resolves():
    for name in repro.__all__:
        assert getattr(repro, name) is not None, name


def test_sim_reexports_mirror_sim_all():
    import repro.sim
    assert set(repro._SIM_EXPORTS) == set(repro.sim.__all__)


def test_cost_model_reexports_mirror_module_all():
    """The ISSUE 4 seam: repro's cost-model re-exports must mirror
    ``repro.core.cost_model.__all__`` and resolve from ``repro.core`` too."""
    import repro.core
    import repro.core.cost_model as cmod
    assert set(repro._COST_MODEL_EXPORTS) == set(cmod.__all__)
    for name in cmod.__all__:
        assert getattr(repro, name) is getattr(cmod, name), name
        assert getattr(repro.core, name) is getattr(cmod, name), name


def test_memory_budgeted_exported_everywhere():
    import repro.sim
    assert repro.MemoryBudgeted is repro.sim.MemoryBudgeted
    assert "MemoryBudgeted" in repro.sim.__all__


def test_all_is_sorted_union_of_submodules_and_sim_exports():
    assert repro.__all__ == sorted(repro._SUBMODULES | repro._SIM_EXPORTS
                                   | repro._COST_MODEL_EXPORTS)


def test_unknown_attribute_raises():
    with pytest.raises(AttributeError):
        repro.definitely_not_a_thing


def test_dir_covers_all():
    assert set(repro.__all__) <= set(dir(repro))


@pytest.mark.parametrize("mod", ["core", "sim", "pipeline", "ft", "obs"])
def test_submodule_all_names_resolve(mod):
    m = importlib.import_module(f"repro.{mod}")
    for name in getattr(m, "__all__", ()):
        assert getattr(m, name) is not None, f"repro.{mod}.{name}"
