"""The chaos fuzzer as test infrastructure: determinism, the standing
event-vs-vectorized differential oracle, the zero-trailing-capacity
auto-fallback regression, shrinking, and corpus replay.

The big (>= 500 case) campaign runs in ``benchmarks/bench_robustness.py``
(CI smoke runs a fixed-seed slice); here the oracle runs a tier-1-sized
slice plus every minimized repro committed under ``tests/corpus/``.
"""

import dataclasses
import json
import math
import os

import numpy as np
import pytest

from repro.sim import fuzz as F
from repro.sim.engine import simulate_plan
from repro.sim.scenario import NetworkScenario, PiecewiseTrace, square_wave

CORPUS_DIR = os.path.join(os.path.dirname(__file__), "corpus")


# ---------------------------------------------------------------------------
# Determinism + the fuzzer's own invariants
# ---------------------------------------------------------------------------

def test_fuzz_case_deterministic():
    for seed in (0, 3, 17, 1234):
        a, b = F.fuzz_case(seed), F.fuzz_case(seed)
        assert a == b
        prof_a, net_a, sol_a = F.case_instance(a)
        prof_b, net_b, sol_b = F.case_instance(b)
        assert sol_a == sol_b and len(net_a.nodes) == len(net_b.nodes)
        np.testing.assert_array_equal(net_a.rate, net_b.rate)


def test_fuzzed_scenarios_always_drain_by_default():
    """The default config guarantees finite makespans by construction:
    every family's trace returns to positive capacity."""
    for seed in range(60):
        case = F.fuzz_case(seed)
        assert case.scenario.drains(), seed


def test_fuzz_families_all_reachable():
    """Over a modest seed range every failure family appears (the sampler
    is not silently skipping one)."""
    kinds = set()
    for seed in range(80):
        case = F.fuzz_case(seed)
        for tr in case.scenario.node_mult.values():
            kinds.add("node")
        for tr in case.scenario.link_mult.values():
            kinds.add("link")
            if len(tr.times) > 6:
                kinds.add("dense")         # flapping / drift breakpoints
            if 0.0 in tr.values:
                kinds.add("outage")
    assert {"node", "link", "dense", "outage"} <= kinds, kinds


def test_differential_oracle_slice():
    """Tier-1 slice of the standing campaign: fuzzed scenarios replayed
    through both engines agree to <= 1e-9 and never produce a silent
    infinite makespan."""
    summary = F.run_fuzz(40, seed=2)
    assert summary.ok, summary.failures
    assert summary.max_gap <= 1e-9
    assert summary.vectorized > 0          # the oracle exercises both paths


# ---------------------------------------------------------------------------
# Zero-trailing-capacity: the documented event-engine fallback
# ---------------------------------------------------------------------------

def _dead_link_case(seed: int = 4):
    """A fuzz case whose scenario kills a link the plan actually uses,
    forever (zero trailing capacity)."""
    case = F.fuzz_case(seed)
    _prof, _net, sol = F.case_instance(case)
    a, c = sol.placement[0], sol.placement[1]      # first hop is always used
    dead = PiecewiseTrace((0.0, 0.5), (1.0, 0.0))
    scen = NetworkScenario(link_mult={(a, c): dead})
    return dataclasses.replace(case, scenario=scen)


def test_zero_trailing_capacity_auto_falls_back_to_event():
    case = _dead_link_case()
    prof, net, sol = F.case_instance(case)
    rep = simulate_plan(prof, net, sol, case.b,
                        num_microbatches=case.num_microbatches,
                        scenario=case.scenario, policy=case.policy,
                        engine="auto")
    assert rep.engine == "event"
    assert "zero trailing capacity" in rep.engine_reason
    assert math.isinf(rep.makespan)        # reported, not silently wrong
    with pytest.raises(ValueError, match="zero trailing capacity"):
        simulate_plan(prof, net, sol, case.b,
                      num_microbatches=case.num_microbatches,
                      scenario=case.scenario, policy=case.policy,
                      engine="vectorized")


def test_check_parity_flags_dead_case_not_silent():
    res = F.check_parity(_dead_link_case())
    assert res.engine == "event"
    assert not res.finite
    assert res.gap == 0.0                  # both engines agree it stalls


# ---------------------------------------------------------------------------
# Shrinking + corpus
# ---------------------------------------------------------------------------

def test_shrink_minimizes_while_predicate_holds():
    """Shrink against a synthetic oracle (scenario still slows the run);
    the minimized case must keep failing with strictly simpler content."""
    case = F.fuzz_case(23)
    baseline = F.check_parity(dataclasses.replace(
        case, scenario=NetworkScenario())).makespan

    def failing(c):
        return F.check_parity(c).makespan > baseline * (1 + 1e-12)

    if not failing(case):
        pytest.skip("seed 23 scenario did not slow this instance")
    small = F.shrink_case(case, failing)
    assert failing(small)
    n_traces = len(small.scenario.node_mult) + len(small.scenario.link_mult)
    assert n_traces <= len(case.scenario.node_mult) + \
        len(case.scenario.link_mult)
    assert small.num_microbatches <= case.num_microbatches
    assert small.seed == case.seed         # the instance never changes


def test_shrink_requires_failing_start():
    with pytest.raises(ValueError):
        F.shrink_case(F.fuzz_case(1), lambda c: False)


def test_corpus_roundtrip(tmp_path):
    case = F.fuzz_case(11)
    path = F.save_case(case, str(tmp_path), note="roundtrip")
    loaded = F.load_case(path)
    assert loaded.scenario == case.scenario
    assert (loaded.seed, loaded.b, loaded.num_microbatches,
            loaded.policy) == (case.seed, case.b, case.num_microbatches,
                               case.policy)
    assert loaded.note == "roundtrip"
    [(p, again)] = F.load_corpus(str(tmp_path))
    assert p == path and again == loaded
    assert F.load_corpus(str(tmp_path / "missing")) == []


def test_corpus_rejects_replan_triggers(tmp_path):
    case = F.fuzz_case(1)
    scen = case.scenario.with_replan(1.0, object())
    with pytest.raises(ValueError):
        F.save_case(dataclasses.replace(case, scenario=scen),
                    str(tmp_path))


def test_corpus_replay():
    """CI replays every minimized repro committed under tests/corpus/:
    parity must hold (or the case must be a documented event-only stall,
    which both engines agree on)."""
    corpus = F.load_corpus(CORPUS_DIR)
    assert corpus, "seed corpus missing"
    for path, case in corpus:
        res = F.check_parity(case)
        if case.scenario.drains():
            assert res.ok, (path, res)
        else:
            assert res.engine == "event" and res.gap == 0.0, (path, res)
