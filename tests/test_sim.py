"""Discrete-event engine (repro.sim) — cross-validation vs Eqs. (12)-(14),
event/FIFO semantics, admission policies (FIFO vs 1F1B memory claims),
vectorized-vs-heap engine equivalence, capacity traces, scenarios, and the
replanning driver."""

import json
import math

import numpy as np
import pytest

from repro.core import (EdgeNetwork, Node, SplitSolution,
                        evaluate_under_fluctuation, fill_latency,
                        make_edge_network, ours, pipeline_interval,
                        total_latency, uniform_profile, vgg16_profile)
from repro.core.profiles import ModelProfile
from repro.ft import RateChange, Straggler
from repro.sim import (FIFO, NetworkScenario, OneFOneB, PiecewiseTrace,
                       ReplanTrigger, activation_occupancy, build_tasks,
                       compare_engines, constant, cross_validate,
                       cross_validate_many, gauss_markov,
                       gauss_markov_scenario, iid_piecewise, piecewise,
                       piecewise_cv_scenario, random_instance, resolve_policy,
                       simulate_plan, simulate_with_replanning,
                       stage_activation_highwater, vectorizable,
                       write_chrome_trace)


@pytest.fixture(scope="module")
def paper_plan():
    prof = vgg16_profile(work_units="bytes")
    net = make_edge_network(num_servers=4, num_clients=4, seed=1,
                            kappa=1 / 32.0)
    plan = ours(prof, net, B=64, b0=8)
    return prof, net, plan


# ---------------------------------------------------------------------------
# The standing cross-validation: sim == analytic on deterministic networks
# ---------------------------------------------------------------------------

def test_cross_validation_randomized_triples():
    """>= 20 randomized (profile, network, plan) triples: simulated T_f, T_i
    and L_t match Eqs. (12)-(14) within 1e-6 relative tolerance."""
    checks = cross_validate_many(trials=24, seed=11, rtol=1e-6)
    assert len(checks) == 24
    for c in checks:
        assert c.ok, (c.max_rel_err, c.cuts, c.placement, c.b, c.B)
    assert max(c.max_rel_err for c in checks) < 1e-9


def test_cross_validation_on_planner_output(paper_plan):
    prof, net, plan = paper_plan
    c = cross_validate(prof, net, plan.solution, plan.b, plan.B)
    assert c.ok
    assert c.L_t_ana == pytest.approx(plan.L_t, rel=1e-9)


def test_single_microbatch_degenerates_to_fill():
    prof, net, sol, b, _ = random_instance(3)
    rep = simulate_plan(prof, net, sol, b, B=b)   # one slot: L_t == T_f
    assert rep.num_microbatches == 1
    assert rep.T_i == 0.0
    assert rep.L_t == pytest.approx(fill_latency(prof, net, sol, b), rel=1e-9)


# ---------------------------------------------------------------------------
# Event ordering + resource-contention semantics
# ---------------------------------------------------------------------------

def _per_resource(records):
    by_res = {}
    for r in records:
        by_res.setdefault(r.resource, []).append(r)
    return by_res


def test_event_ordering_and_fifo():
    prof, net, sol, b, B = random_instance(5)
    rep = simulate_plan(prof, net, sol, b, B=B)
    for recs in _per_resource(rep.records).values():
        recs = sorted(recs, key=lambda r: r.start)
        # one-at-a-time service: intervals never overlap
        for a, c in zip(recs, recs[1:]):
            assert c.start >= a.end - 1e-12
        # FIFO: a linear pipeline visits each resource in micro-batch order
        assert [r.microbatch for r in recs] == sorted(
            r.microbatch for r in recs)
    # chain precedence: within a micro-batch, records appear in chain order
    for m in range(rep.num_microbatches):
        chain = [r for r in rep.records if r.microbatch == m]
        chain.sort(key=lambda r: (r.start, r.end))
        for a, c in zip(chain, chain[1:]):
            assert c.start >= a.end - 1e-12


def test_colocated_stages_contend():
    """Two submodels on one node serialize on its FP/BP engines (the C9-C16
    co-location sums), and the fill latency still equals Eq. (12)."""
    prof = uniform_profile(8, fp=1.0, bp=2.0, act=1.0)
    net = make_edge_network(num_servers=3, num_clients=1, seed=0)
    sol = SplitSolution(cuts=(2, 4, 6, 8), placement=(0, 1, 2, 1))
    b, B = 4, 32
    # a solo micro-batch sees no contention: fill == Eq. (12) exactly
    solo = simulate_plan(prof, net, sol, b, num_microbatches=1)
    assert solo.L_t == pytest.approx(fill_latency(prof, net, sol, b),
                                     rel=1e-9)
    rep = simulate_plan(prof, net, sol, b, B=B)
    # under pipelining, trailing micro-batches occupy the shared engine
    # before mb0 returns to it — observed fill can only inflate
    assert rep.T_f >= solo.L_t - 1e-12
    by_res = _per_resource(rep.records)
    # node 1 hosts stages 1 and 3: its fp engine serves both, serialized
    fp1 = sorted(by_res[("fp", 1)], key=lambda r: r.start)
    assert {r.stage for r in fp1} == {1, 3}
    for a, c in zip(fp1, fp1[1:]):
        assert c.start >= a.end - 1e-12
    # work conservation: makespan >= the busiest resource's total work
    for recs in by_res.values():
        assert rep.L_t >= sum(r.duration for r in recs) - 1e-9
    # Eq. (14) assumes a perfectly interleaved cyclic schedule on the shared
    # engine; greedy FIFO on a reentrant line deviates from it in either
    # direction, but only by bounded idle time — a gross engine bug (e.g.
    # lost serialization, double service) would blow well past this
    ana = total_latency(prof, net, sol, b, B)
    assert rep.L_t == pytest.approx(ana, rel=0.25)


# ---------------------------------------------------------------------------
# Piecewise traces: integration, outage stalls, Gauss-Markov statistics
# ---------------------------------------------------------------------------

def test_trace_integration_across_breakpoints():
    tr = piecewise((0.0, 1.0, 3.0), (2.0, 0.5, 4.0))
    assert tr.time_to_complete(0.0, 1.0) == pytest.approx(0.5)
    # 2.0 work: [0,1) serves 2.0 exactly
    assert tr.time_to_complete(0.0, 2.0) == pytest.approx(1.0)
    # 2.5 work: 2.0 in [0,1), 0.5 more at rate 0.5 -> t=2.0
    assert tr.time_to_complete(0.0, 2.5) == pytest.approx(2.0)
    # starting mid-segment
    assert tr.time_to_complete(0.5, 1.0) == pytest.approx(0.5)
    assert tr.value_at(2.9) == 0.5 and tr.value_at(3.0) == 4.0


def test_trace_zero_segment_stalls_and_trailing_zero_is_inf():
    tr = piecewise((0.0, 1.0, 2.0), (1.0, 0.0, 1.0))
    # 1.5 work from t=0: 1.0 by t=1, stall on [1,2), finish 0.5 at t=2.5
    assert tr.time_to_complete(0.0, 1.5) == pytest.approx(2.5)
    dead = piecewise((0.0, 1.0), (1.0, 0.0))
    assert math.isinf(dead.time_to_complete(0.5, 1.0))


def test_trace_product_merges_breakpoints():
    a = piecewise((0.0, 2.0), (1.0, 3.0))
    b = piecewise((0.0, 1.0), (2.0, 0.5))
    p = a * b
    for t in (0.0, 0.5, 1.0, 1.5, 2.0, 5.0):
        assert p.value_at(t) == pytest.approx(a.value_at(t) * b.value_at(t))


def test_gauss_markov_stationary_stats():
    rng = np.random.default_rng(0)
    tr = gauss_markov(rng, cv=0.2, dt=1.0, horizon=20000.0, corr=0.9)
    vals = np.asarray(tr.values)
    assert vals.mean() == pytest.approx(1.0, abs=0.03)
    assert vals.std() == pytest.approx(0.2, abs=0.03)
    # correlated: lag-1 autocorrelation near corr
    v = vals - vals.mean()
    rho = (v[:-1] * v[1:]).mean() / (v.var() + 1e-12)
    assert rho == pytest.approx(0.9, abs=0.05)


def test_cv_zero_scenarios_are_constant():
    rng = np.random.default_rng(0)
    assert iid_piecewise(rng, 0.0, dt=1.0, horizon=10.0).is_constant()
    assert gauss_markov(rng, 0.0, dt=1.0, horizon=10.0).is_constant()


# ---------------------------------------------------------------------------
# Scenario injection: stragglers, outages, time-varying capacity
# ---------------------------------------------------------------------------

def test_straggler_window_slows_pipeline(paper_plan):
    prof, net, plan = paper_plan
    base = simulate_plan(prof, net, plan.solution, plan.b, B=plan.B)
    node = plan.solution.placement[1]
    scen = NetworkScenario().with_straggler(node, 0.0, base.L_t, 8.0)
    slow = simulate_plan(prof, net, plan.solution, plan.b, B=plan.B,
                         scenario=scen)
    assert slow.L_t > base.L_t


def test_outage_stalls_transfer(paper_plan):
    prof, net, plan = paper_plan
    base = simulate_plan(prof, net, plan.solution, plan.b, B=plan.B)
    a, c = plan.solution.placement[0], plan.solution.placement[1]
    t_out = 5.0 * base.L_t
    scen = NetworkScenario().with_outage(a, c, 0.0, t_out)
    rep = simulate_plan(prof, net, plan.solution, plan.b, B=plan.B,
                        scenario=scen)
    # the first activation transfer cannot complete before the outage lifts
    assert rep.T_f >= t_out


def test_time_varying_scenarios_run(paper_plan):
    prof, net, plan = paper_plan
    base = simulate_plan(prof, net, plan.solution, plan.b, B=plan.B)
    rng = np.random.default_rng(1)
    for make in (piecewise_cv_scenario, gauss_markov_scenario):
        scen = make(net, 0.3, rng, dt=base.L_t / 16, horizon=4 * base.L_t)
        rep = simulate_plan(prof, net, plan.solution, plan.b, B=plan.B,
                            scenario=scen)
        assert np.isfinite(rep.L_t) and rep.L_t > 0
        assert np.all(np.diff(rep.mb_complete) > -1e-12)


# ---------------------------------------------------------------------------
# Trace-driven fluctuation evaluation (Fig. 6b path)
# ---------------------------------------------------------------------------

def test_fluctuation_trace_mode(paper_plan):
    prof, net, plan = paper_plan
    r0 = evaluate_under_fluctuation(prof, net, plan, 0.0, draws=2, seed=0,
                                    mode="trace")
    assert r0.degradation == pytest.approx(1.0, rel=1e-9)
    for model in ("piecewise", "gauss_markov"):
        r = evaluate_under_fluctuation(prof, net, plan, 0.25, draws=4,
                                       seed=0, mode="trace",
                                       trace_model=model)
        assert np.isfinite(r.mean_latency) and r.mean_latency > 0
        assert r.p95_latency >= r.mean_latency - 1e-12


def test_fluctuation_iid_mode_unchanged(paper_plan):
    """The default path must keep producing the original i.i.d. numbers."""
    import repro.core.latency as L
    prof, net, plan = paper_plan
    r = evaluate_under_fluctuation(prof, net, plan, 0.1, draws=8, seed=3)
    rng = np.random.default_rng(3)
    expect = [L.total_latency(prof, net.with_fluctuation(rng, 0.1),
                              plan.solution, plan.b, plan.B)
              for _ in range(8)]
    assert r.mean_latency == pytest.approx(float(np.mean(expect)), rel=1e-12)


def test_fluctuation_rejects_unknown_mode(paper_plan):
    prof, net, plan = paper_plan
    with pytest.raises(ValueError):
        evaluate_under_fluctuation(prof, net, plan, 0.1, mode="nope")


# ---------------------------------------------------------------------------
# Replanning driven by simulated time
# ---------------------------------------------------------------------------

def test_replanning_driver(paper_plan):
    prof, net, plan = paper_plan
    base = simulate_plan(prof, net, plan.solution, plan.b, B=plan.B)
    node = plan.solution.placement[1]
    trig = [ReplanTrigger(0.4 * base.L_t, Straggler(node, 6.0)),
            ReplanTrigger(0.9 * base.L_t, RateChange(0, node, 0.5))]
    rep = simulate_with_replanning(prof, net, plan.B, trig)
    assert rep.num_replans == 2
    assert np.isfinite(rep.makespan)
    # a straggler + rate drop can only hurt vs the undisturbed run
    assert rep.makespan >= base.L_t - 1e-9
    # every sample is accounted for across segments
    samples = sum(s.completed * s.plan.b for s in rep.segments)
    assert samples >= plan.B
    assert all(s.outcome.action in ("replan", "microbatch")
               for s in rep.segments if s.outcome is not None)


def test_replanning_consumes_scenario_triggers(paper_plan):
    """Triggers composed onto the scenario via with_replan fire too."""
    prof, net, plan = paper_plan
    base = simulate_plan(prof, net, plan.solution, plan.b, B=plan.B)
    node = plan.solution.placement[1]
    scen = NetworkScenario().with_replan(0.5 * base.L_t, Straggler(node, 6.0))
    rep = simulate_with_replanning(prof, net, plan.B, scenario=scen)
    assert rep.num_replans == 1


def test_replanning_rejects_node_failure_with_scenario(paper_plan):
    """NodeFailure renumbers indices; index-keyed scenario traces would
    silently land on the wrong nodes — must be rejected."""
    from repro.ft import NodeFailure
    prof, net, plan = paper_plan
    scen = NetworkScenario().with_straggler(1, 0.0, 1.0, 2.0)
    with pytest.raises(ValueError, match="NodeFailure"):
        simulate_with_replanning(prof, net, plan.B,
                                 [ReplanTrigger(0.01, NodeFailure(2))],
                                 scenario=scen)


def test_replanning_no_triggers_matches_plain_sim(paper_plan):
    prof, net, plan = paper_plan
    rep = simulate_with_replanning(prof, net, plan.B, [])
    plain = simulate_plan(prof, net, rep.coordinator.plan.solution,
                          rep.coordinator.plan.b, B=plan.B)
    assert rep.makespan == pytest.approx(plain.L_t, rel=1e-9)


# ---------------------------------------------------------------------------
# Chrome trace export
# ---------------------------------------------------------------------------

def test_chrome_trace_export(tmp_path, paper_plan):
    prof, net, plan = paper_plan
    rep = simulate_plan(prof, net, plan.solution, plan.b, B=plan.B)
    path = write_chrome_trace(rep.records, str(tmp_path / "trace.json"))
    with open(path) as f:
        data = json.load(f)
    evs = [e for e in data["traceEvents"] if e["ph"] == "X"]
    assert len(evs) == len(rep.records)
    assert all({"name", "ts", "dur", "pid", "tid"} <= set(e) for e in evs)
    names = [e["args"]["name"] for e in data["traceEvents"]
             if e["ph"] == "M"]
    assert any(n.startswith("node0:fp") for n in names)


def test_build_tasks_chain_shape():
    prof, net, sol, b, _ = random_instance(9)
    m = 3
    tasks = build_tasks(prof, net, sol, b, m)
    K = len(list(sol.segments()))
    assert len(tasks) == m * (2 * K + 2 * (K - 1))
    roots = [t for t in tasks if t.dep is None]
    assert len(roots) == m                       # one chain per micro-batch
    assert all(t.resource == ("fp", 0) for t in roots)


# ---------------------------------------------------------------------------
# Admission policies: FIFO bit-identity, 1F1B windows, memory claims
# ---------------------------------------------------------------------------

def _saturating_instance(S=4, Q=12):
    """Distinct-placement chain whose *final* BP dominates everything: every
    earlier resource drains instantly, so each stage buffers as many live
    activations as its admission policy permits — the claims are achieved
    exactly, not just bounded."""
    fp = np.full(S, 1e-3)
    bp = np.full(S, 1e-3)
    bp[-1] = 10.0
    prof = ModelProfile(name="sat", fp_work=fp, bp_work=bp,
                        act_bytes=np.full(S, 1.0),
                        grad_bytes=np.full(S, 1.0),
                        param_bytes=np.zeros(S), opt_bytes=np.zeros(S))
    nodes = [Node("c", f=1.0, t0=0.0, t1=0.0, b_th=0, is_client=True)]
    nodes += [Node(f"s{i}", f=1.0, t0=0.0, t1=0.0, b_th=0)
              for i in range(1, S)]
    rate = np.full((S, S), 1e6)
    np.fill_diagonal(rate, 0.0)
    net = EdgeNetwork(nodes=nodes, rate=rate, num_clients=1)
    sol = SplitSolution(cuts=tuple(range(1, S + 1)),
                        placement=tuple(range(S)))
    return prof, net, sol, Q


def _record_tuple(rec):
    return (rec.microbatch, rec.stage, rec.kind, rec.resource, rec.start,
            rec.end)


def test_fifo_policy_is_the_pr1_engine():
    """FIFO must reproduce PR 1 timelines bit-identically: it contributes
    zero extra edges, so the default heap event loop is untouched."""
    prof, net, sol, b, B = random_instance(7)
    tasks = build_tasks(prof, net, sol, b, 4)
    assert FIFO().extra_dependencies(tasks) == []
    rep = simulate_plan(prof, net, sol, b, B=B)        # defaults
    assert rep.engine == "event" and rep.policy == "fifo"
    explicit = simulate_plan(prof, net, sol, b, B=B, policy="fifo",
                             engine="event")
    assert [_record_tuple(r) for r in rep.records] == \
           [_record_tuple(r) for r in explicit.records]


def test_policy_resolution_and_windows():
    assert resolve_policy("gpipe").name == "fifo"
    assert resolve_policy(OneFOneB()).name == "1f1b"
    with pytest.raises(ValueError, match="unknown admission policy"):
        resolve_policy("round-robin")
    one = OneFOneB()
    assert [one.window(4, j) for j in range(4)] == [4, 3, 2, 1]
    assert FIFO().window(4, 0) is None


def test_engines_agree_under_both_policies():
    """Heap engine vs vectorized engine: identical micro-batch completion
    times (to float noise) wherever the vectorized engine is eligible."""
    hits = 0
    for seed in range(12):
        prof, net, sol, b, B = random_instance(31 * seed + 2)
        if not vectorizable(prof, net, sol, b):
            continue
        hits += 1
        Q = 1 + math.ceil((B - b) / b)
        for pol in ("fifo", "1f1b"):
            assert compare_engines(prof, net, sol, b, Q, policy=pol) < 1e-9
    assert hits >= 8        # the generator yields distinct placements


def test_vectorized_engine_covers_reentrant_and_traces():
    """The ISSUE 5 generalizations: co-located (reentrant) placements run
    the merged-scan fixpoint and time-varying scenarios the segmented trace
    scans — both vectorized, both matching the heap engine exactly."""
    prof = uniform_profile(8, fp=1.0, bp=2.0, act=1.0)
    net = make_edge_network(num_servers=3, num_clients=1, seed=0)
    colocated = SplitSolution(cuts=(2, 4, 6, 8), placement=(0, 1, 2, 1))
    assert vectorizable(prof, net, colocated, 4)
    rep = simulate_plan(prof, net, colocated, 4, B=16, engine="vectorized")
    assert rep.engine == "vectorized"
    assert "fixpoint" in rep.engine_reason
    assert compare_engines(prof, net, colocated, 4, 8) < 1e-9
    # solo micro-batch still matches Eq. (12) exactly (no contention)
    solo = simulate_plan(prof, net, colocated, 4, num_microbatches=1,
                         engine="vectorized")
    assert solo.L_t == pytest.approx(fill_latency(prof, net, colocated, 4),
                                     rel=1e-9)
    # a time-varying scenario stays vectorized under "auto" as well
    distinct = SplitSolution(cuts=(2, 4, 8), placement=(0, 1, 2))
    scen = NetworkScenario().with_straggler(1, 0.0, 1.0, 2.0)
    rep = simulate_plan(prof, net, distinct, 4, num_microbatches=2,
                        scenario=scen, engine="auto")
    assert rep.engine == "vectorized"
    assert "trace" in rep.engine_reason
    assert compare_engines(prof, net, distinct, 4, 6, scenario=scen) < 1e-9
    # ... and an all-constant scenario uses the constant-capacity scans
    rep = simulate_plan(prof, net, distinct, 4, num_microbatches=2,
                        scenario=NetworkScenario(), engine="auto")
    assert rep.engine == "vectorized"
    assert "constant-capacity" in rep.engine_reason


def test_vectorized_raises_with_violated_precondition():
    """No silent fallback under engine='vectorized': the error names the
    violated precondition (here: a used resource that can never finish),
    while engine='auto' records why the event engine ran."""
    prof = uniform_profile(4, fp=1.0, bp=1.0, act=1.0)
    nodes = [Node("c", f=1.0, t0=0.0, t1=0.0, b_th=0, is_client=True),
             Node("s", f=1.0, t0=0.0, t1=0.0, b_th=0)]
    net = EdgeNetwork(nodes=nodes, rate=np.zeros((2, 2)), num_clients=1)
    sol = SplitSolution(cuts=(2, 4), placement=(0, 1))
    assert not vectorizable(prof, net, sol, 1)
    with pytest.raises(ValueError, match="cannot finish its work"):
        simulate_plan(prof, net, sol, 1, num_microbatches=2,
                      engine="vectorized")
    rep = simulate_plan(prof, net, sol, 1, num_microbatches=1,
                        engine="auto")
    assert rep.engine == "event"
    assert "cannot finish its work" in rep.engine_reason
    # a dead *trace* (outage that never lifts) is detected the same way
    net2 = EdgeNetwork(nodes=nodes, rate=np.full((2, 2), 10.0),
                       num_clients=1)
    dead = NetworkScenario(link_mult={(0, 1): constant(0.0)})
    assert not vectorizable(prof, net2, sol, 1, scenario=dead)
    with pytest.raises(ValueError, match="zero trailing capacity"):
        simulate_plan(prof, net2, sol, 1, num_microbatches=2, scenario=dead,
                      engine="vectorized")


def test_engine_reason_reported():
    prof, net, sol, b, B = random_instance(5)
    assert simulate_plan(prof, net, sol, b, B=B).engine_reason \
        == "event: requested"
    assert "column scans" in simulate_plan(
        prof, net, sol, b, B=B, engine="auto").engine_reason
    assert "windowed scan" in simulate_plan(
        prof, net, sol, b, B=B, engine="auto", policy="1f1b").engine_reason


# ---------------------------------------------------------------------------
# Randomized parity grid: traces x reentrant placements x policies
# ---------------------------------------------------------------------------

def _grid_instance(seed: int, reentrant: bool, cv: float, model: str):
    """One randomized instance for the engine-parity grid."""
    from repro.core.profiles import random_profile
    from repro.sim import random_chain_solution, random_reentrant_solution
    rng = np.random.default_rng(seed)
    prof = random_profile(rng, int(rng.integers(5, 11)))
    net = make_edge_network(num_servers=int(rng.integers(2, 5)),
                            num_clients=int(rng.integers(1, 4)), seed=seed)
    if reentrant:
        sol = random_reentrant_solution(rng, prof, net)
    else:
        sol = random_chain_solution(rng, prof, net)
    b = int(rng.integers(1, 9))
    Q = int(rng.integers(2, 14))
    scen = None
    if cv > 0:
        maker = (piecewise_cv_scenario if model == "piecewise"
                 else gauss_markov_scenario)
        scen = maker(net, cv, rng, dt=0.02, horizon=5.0)
    return prof, net, sol, b, Q, scen


@pytest.mark.parametrize("reentrant", [False, True])
@pytest.mark.parametrize("cv,model", [(0.0, "piecewise"),
                                      (0.3, "piecewise"),
                                      (0.3, "gauss_markov")])
def test_engine_parity_grid(reentrant, cv, model):
    """Heap vs vectorized on randomized piecewise traces x reentrant plans
    x all three admission policies: identical completion times to float
    noise (the ISSUE 5 acceptance grid)."""
    hits = 0
    for seed in range(6):
        prof, net, sol, b, Q, scen = _grid_instance(
            101 * seed + 13, reentrant, cv, model)
        for pol in ("fifo", "1f1b", "memory"):
            try:
                gap = compare_engines(prof, net, sol, b, Q, policy=pol,
                                      scenario=scen)
            except ValueError:
                continue          # memory-infeasible under the budget
            assert gap < 1e-9, (seed, pol, gap)
            hits += 1
    assert hits >= 10


def test_engine_parity_hypothesis():
    """Property-based twin of the parity grid (skips without hypothesis)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 10_000), reentrant=st.booleans(),
           cv=st.sampled_from([0.0, 0.25, 0.5]),
           pol=st.sampled_from(["fifo", "1f1b", "memory"]),
           model=st.sampled_from(["piecewise", "gauss_markov"]))
    def run(seed, reentrant, cv, pol, model):
        prof, net, sol, b, Q, scen = _grid_instance(seed, reentrant, cv,
                                                    model)
        try:
            gap = compare_engines(prof, net, sol, b, Q, policy=pol,
                                  scenario=scen)
        except ValueError:
            return                # memory-infeasible under the budget
        assert gap < 1e-9

    run()


def test_simulate_plans_mixed_kind_reentrant_group_exact():
    """A reentrant resource whose visits mix serving kinds (a zero-work
    visit co-located with a traced one) must not be silently mis-served by
    the stacked fixpoint — the batched path declines such structures and
    the per-plan merged scan (scalar kind) stays exact."""
    import dataclasses
    from repro.sim import simulate_plans
    prof = uniform_profile(8, fp=1.0, bp=2.0, act=1.0)
    fp = np.ones(8)
    fp[6:] = 0.0                   # stage at layers 7-8: zero FP work
    prof = dataclasses.replace(prof, fp_work=fp)
    nodes = [Node("c", f=1.0, t0=0.0, t1=0.0, b_th=0, is_client=True)]
    nodes += [Node(f"s{i}", f=1.0, t0=0.0, t1=0.0, b_th=0)
              for i in (1, 2)]
    rate = np.full((3, 3), 10.0)
    np.fill_diagonal(rate, 0.0)
    net = EdgeNetwork(nodes=nodes, rate=rate, num_clients=1)
    sol = SplitSolution(cuts=(2, 4, 6, 8), placement=(0, 1, 2, 1))
    rng = np.random.default_rng(7)
    scen = gauss_markov_scenario(net, 0.4, rng, dt=0.05, horizon=500.0)
    plans = [(sol, b) for b in (1, 2, 3)]
    loop = [simulate_plan(prof, net, s, b, B=9, scenario=scen,
                          engine="auto") for s, b in plans]
    bat = simulate_plans(prof, net, plans, B=9, scenario=scen,
                         engine="auto")
    ev = [simulate_plan(prof, net, s, b, B=9, scenario=scen,
                        engine="event") for s, b in plans]
    for lr, br, er in zip(loop, bat, ev):
        assert np.array_equal(lr.mb_complete, br.mb_complete)
        gap = np.max(np.abs(er.mb_complete - br.mb_complete)
                     / np.maximum(np.abs(er.mb_complete), 1e-30))
        assert gap < 1e-9


def test_simulate_plans_matches_looped_simulate_plan():
    """The batched multi-plan path (stacked plan axis + stacked fixpoint)
    returns exactly the per-plan reports' completion times."""
    from repro.sim import simulate_plans
    prof = uniform_profile(8, fp=1.0, bp=2.0, act=1.0)
    net = make_edge_network(num_servers=3, num_clients=1, seed=0)
    sols = [SplitSolution(cuts=(2, 4, 8), placement=(0, 1, 2)),     # chain
            SplitSolution(cuts=(2, 4, 6, 8), placement=(0, 1, 2, 1))]  # re.
    for sol in sols:
        plans = [(sol, b) for b in (1, 2, 3, 4)]
        for pol in ("fifo", "1f1b"):
            loop = [simulate_plan(prof, net, s, b, B=12, policy=pol,
                                  engine="auto") for s, b in plans]
            bat = simulate_plans(prof, net, plans, B=12, policy=pol,
                                 engine="auto")
            for lr, br in zip(loop, bat):
                assert np.array_equal(lr.mb_complete, br.mb_complete)


def test_highwater_never_exceeds_schedule_claims():
    """Event-by-event: measured per-stage activation occupancy stays within
    the closed-form claims of pipeline.schedule for every random instance,
    under both policies and both engines."""
    from repro.pipeline.schedule import memory_highwater
    for seed in (1, 5, 9):
        prof, net, sol, b, B = random_instance(seed)
        Q = 1 + math.ceil((B - b) / b)
        S = len(list(sol.segments()))
        for pol in ("fifo", "1f1b"):
            claims = memory_highwater(S, Q, pol)
            for eng in ("event", "auto"):
                rep = simulate_plan(prof, net, sol, b, num_microbatches=Q,
                                    policy=pol, engine=eng)
                occ = activation_occupancy(rep.records)
                assert set(occ) == set(claims)
                for j, series in occ.items():
                    for _, level in series:       # every event, every stage
                        assert level <= claims[j]


def test_1f1b_highwater_matches_schedule_claims_exactly():
    """On a pipeline whose claims are achievable, the engine's measured
    high-water marks equal pipeline.schedule's closed form — stage by
    stage, for both the GPipe and the 1F1B claim."""
    from repro.pipeline.schedule import memory_highwater
    prof, net, sol, Q = _saturating_instance(S=4, Q=12)
    for pol in ("fifo", "1f1b"):
        rep = simulate_plan(prof, net, sol, 1, num_microbatches=Q,
                            policy=pol, engine="event")
        assert stage_activation_highwater(rep.records) == \
            memory_highwater(4, Q, pol)
    # and with fewer micro-batches than stages the claims clip at Q
    small = simulate_plan(prof, net, sol, 1, num_microbatches=2,
                          policy="1f1b", engine="event")
    assert stage_activation_highwater(small.records) == \
        memory_highwater(4, 2, "1f1b")


def test_1f1b_trades_latency_for_memory():
    prof, net, sol, Q = _saturating_instance(S=4, Q=12)
    fifo = simulate_plan(prof, net, sol, 1, num_microbatches=Q,
                         policy="fifo")
    one = simulate_plan(prof, net, sol, 1, num_microbatches=Q,
                        policy="1f1b")
    assert one.L_t >= fifo.L_t - 1e-9          # admission can only delay
    hw_f = stage_activation_highwater(fifo.records)
    hw_1 = stage_activation_highwater(one.records)
    assert all(hw_1[j] <= hw_f[j] for j in hw_f)
    assert hw_1[0] < hw_f[0]                   # strictly fewer live buffers


def test_zero_microbatches_empty_report_on_both_engines():
    prof, net, sol, b, _ = random_instance(3)
    for pol in ("fifo", "1f1b"):
        for eng in ("event", "vectorized"):
            rep = simulate_plan(prof, net, sol, b, num_microbatches=0,
                                policy=pol, engine=eng)
            assert rep.num_microbatches == 0
            assert len(rep.mb_complete) == 0 and rep.records == []
            assert rep.L_t == 0.0 and rep.resource_busy == {}


def test_single_microbatch_identical_across_policies_and_engines():
    prof, net, sol, b, _ = random_instance(3)
    want = fill_latency(prof, net, sol, b)
    for pol in ("fifo", "1f1b"):
        for eng in ("event", "auto"):
            rep = simulate_plan(prof, net, sol, b, B=b, policy=pol,
                                engine=eng)
            assert rep.num_microbatches == 1
            assert rep.T_i == 0.0
            assert rep.L_t == pytest.approx(want, rel=1e-9)


def test_vectorized_report_timeline_and_lazy_records():
    prof, net, sol, b, B = random_instance(5)
    rep = simulate_plan(prof, net, sol, b, B=B, engine="vectorized")
    assert rep.engine == "vectorized" and rep.timeline is not None
    Q, R = rep.timeline.starts.shape
    assert Q == rep.num_microbatches
    assert len(rep.records) == Q * R             # materialized on demand
    assert rep.records is rep.records            # and cached
    # the dense timeline respects chain order and non-negative service
    assert np.all(rep.timeline.ends >= rep.timeline.starts - 1e-12)
    assert np.all(np.diff(rep.timeline.ends, axis=1) >= -1e-12)


# ---------------------------------------------------------------------------
# Scenario edge cases: zero-length segments/windows, overlapping windows
# ---------------------------------------------------------------------------

def test_piecewise_coalesces_zero_length_segments():
    tr = piecewise((0.0, 1.0, 1.0, 2.0), (1.0, 99.0, 2.0, 3.0))
    assert tr.times == (0.0, 1.0, 2.0)
    assert tr.values == (1.0, 2.0, 3.0)          # last value wins at t=1
    # the strict dataclass keeps rejecting non-increasing breakpoints
    with pytest.raises(ValueError, match="strictly increasing"):
        PiecewiseTrace((0.0, 1.0, 1.0), (1.0, 2.0, 3.0))


def test_zero_length_windows_are_identity(paper_plan):
    prof, net, plan = paper_plan
    base = simulate_plan(prof, net, plan.solution, plan.b, B=plan.B)
    node = plan.solution.placement[1]
    scen = NetworkScenario().with_straggler(node, 2.0, 2.0, 8.0)
    scen = scen.with_outage(plan.solution.placement[0], node, 1.0, 1.0)
    same = simulate_plan(prof, net, plan.solution, plan.b, B=plan.B,
                         scenario=scen)
    assert same.L_t == pytest.approx(base.L_t, rel=1e-12)


def test_outage_overlapping_straggler_compounds(paper_plan):
    """An outage window overlapping a straggler window on the same span:
    the run stays finite, and the combination is at least as slow as either
    perturbation alone (slower resources cannot speed a FIFO pipeline)."""
    prof, net, plan = paper_plan
    base = simulate_plan(prof, net, plan.solution, plan.b, B=plan.B)
    node = plan.solution.placement[1]
    a = plan.solution.placement[0]
    t_mid = 0.5 * base.L_t
    strag = NetworkScenario().with_straggler(node, 0.0, t_mid, 6.0)
    outage = NetworkScenario().with_outage(a, node, 0.25 * base.L_t, t_mid)
    both = strag.with_outage(a, node, 0.25 * base.L_t, t_mid)
    r_s = simulate_plan(prof, net, plan.solution, plan.b, B=plan.B,
                        scenario=strag)
    r_o = simulate_plan(prof, net, plan.solution, plan.b, B=plan.B,
                        scenario=outage)
    r_b = simulate_plan(prof, net, plan.solution, plan.b, B=plan.B,
                        scenario=both)
    assert np.isfinite(r_b.L_t)
    assert r_b.L_t >= max(r_s.L_t, r_o.L_t) - 1e-9
    # ... under 1F1B admission too
    r_b1 = simulate_plan(prof, net, plan.solution, plan.b, B=plan.B,
                         scenario=both, policy="1f1b")
    assert np.isfinite(r_b1.L_t) and r_b1.L_t >= r_b.L_t - 1e-9
