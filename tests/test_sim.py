"""Discrete-event engine (repro.sim) — cross-validation vs Eqs. (12)-(14),
event/FIFO semantics, capacity traces, scenarios, and the replanning driver."""

import json
import math

import numpy as np
import pytest

from repro.core import (SplitSolution, evaluate_under_fluctuation,
                        fill_latency, make_edge_network, ours,
                        pipeline_interval, total_latency, uniform_profile,
                        vgg16_profile)
from repro.ft import RateChange, Straggler
from repro.sim import (NetworkScenario, PiecewiseTrace, ReplanTrigger,
                       build_tasks, constant, cross_validate,
                       cross_validate_many, gauss_markov,
                       gauss_markov_scenario, iid_piecewise, piecewise,
                       piecewise_cv_scenario, random_instance, simulate_plan,
                       simulate_with_replanning, write_chrome_trace)


@pytest.fixture(scope="module")
def paper_plan():
    prof = vgg16_profile(work_units="bytes")
    net = make_edge_network(num_servers=4, num_clients=4, seed=1,
                            kappa=1 / 32.0)
    plan = ours(prof, net, B=64, b0=8)
    return prof, net, plan


# ---------------------------------------------------------------------------
# The standing cross-validation: sim == analytic on deterministic networks
# ---------------------------------------------------------------------------

def test_cross_validation_randomized_triples():
    """>= 20 randomized (profile, network, plan) triples: simulated T_f, T_i
    and L_t match Eqs. (12)-(14) within 1e-6 relative tolerance."""
    checks = cross_validate_many(trials=24, seed=11, rtol=1e-6)
    assert len(checks) == 24
    for c in checks:
        assert c.ok, (c.max_rel_err, c.cuts, c.placement, c.b, c.B)
    assert max(c.max_rel_err for c in checks) < 1e-9


def test_cross_validation_on_planner_output(paper_plan):
    prof, net, plan = paper_plan
    c = cross_validate(prof, net, plan.solution, plan.b, plan.B)
    assert c.ok
    assert c.L_t_ana == pytest.approx(plan.L_t, rel=1e-9)


def test_single_microbatch_degenerates_to_fill():
    prof, net, sol, b, _ = random_instance(3)
    rep = simulate_plan(prof, net, sol, b, B=b)   # one slot: L_t == T_f
    assert rep.num_microbatches == 1
    assert rep.T_i == 0.0
    assert rep.L_t == pytest.approx(fill_latency(prof, net, sol, b), rel=1e-9)


# ---------------------------------------------------------------------------
# Event ordering + resource-contention semantics
# ---------------------------------------------------------------------------

def _per_resource(records):
    by_res = {}
    for r in records:
        by_res.setdefault(r.resource, []).append(r)
    return by_res


def test_event_ordering_and_fifo():
    prof, net, sol, b, B = random_instance(5)
    rep = simulate_plan(prof, net, sol, b, B=B)
    for recs in _per_resource(rep.records).values():
        recs = sorted(recs, key=lambda r: r.start)
        # one-at-a-time service: intervals never overlap
        for a, c in zip(recs, recs[1:]):
            assert c.start >= a.end - 1e-12
        # FIFO: a linear pipeline visits each resource in micro-batch order
        assert [r.microbatch for r in recs] == sorted(
            r.microbatch for r in recs)
    # chain precedence: within a micro-batch, records appear in chain order
    for m in range(rep.num_microbatches):
        chain = [r for r in rep.records if r.microbatch == m]
        chain.sort(key=lambda r: (r.start, r.end))
        for a, c in zip(chain, chain[1:]):
            assert c.start >= a.end - 1e-12


def test_colocated_stages_contend():
    """Two submodels on one node serialize on its FP/BP engines (the C9-C16
    co-location sums), and the fill latency still equals Eq. (12)."""
    prof = uniform_profile(8, fp=1.0, bp=2.0, act=1.0)
    net = make_edge_network(num_servers=3, num_clients=1, seed=0)
    sol = SplitSolution(cuts=(2, 4, 6, 8), placement=(0, 1, 2, 1))
    b, B = 4, 32
    # a solo micro-batch sees no contention: fill == Eq. (12) exactly
    solo = simulate_plan(prof, net, sol, b, num_microbatches=1)
    assert solo.L_t == pytest.approx(fill_latency(prof, net, sol, b),
                                     rel=1e-9)
    rep = simulate_plan(prof, net, sol, b, B=B)
    # under pipelining, trailing micro-batches occupy the shared engine
    # before mb0 returns to it — observed fill can only inflate
    assert rep.T_f >= solo.L_t - 1e-12
    by_res = _per_resource(rep.records)
    # node 1 hosts stages 1 and 3: its fp engine serves both, serialized
    fp1 = sorted(by_res[("fp", 1)], key=lambda r: r.start)
    assert {r.stage for r in fp1} == {1, 3}
    for a, c in zip(fp1, fp1[1:]):
        assert c.start >= a.end - 1e-12
    # work conservation: makespan >= the busiest resource's total work
    for recs in by_res.values():
        assert rep.L_t >= sum(r.duration for r in recs) - 1e-9
    # Eq. (14) assumes a perfectly interleaved cyclic schedule on the shared
    # engine; greedy FIFO on a reentrant line deviates from it in either
    # direction, but only by bounded idle time — a gross engine bug (e.g.
    # lost serialization, double service) would blow well past this
    ana = total_latency(prof, net, sol, b, B)
    assert rep.L_t == pytest.approx(ana, rel=0.25)


# ---------------------------------------------------------------------------
# Piecewise traces: integration, outage stalls, Gauss-Markov statistics
# ---------------------------------------------------------------------------

def test_trace_integration_across_breakpoints():
    tr = piecewise((0.0, 1.0, 3.0), (2.0, 0.5, 4.0))
    assert tr.time_to_complete(0.0, 1.0) == pytest.approx(0.5)
    # 2.0 work: [0,1) serves 2.0 exactly
    assert tr.time_to_complete(0.0, 2.0) == pytest.approx(1.0)
    # 2.5 work: 2.0 in [0,1), 0.5 more at rate 0.5 -> t=2.0
    assert tr.time_to_complete(0.0, 2.5) == pytest.approx(2.0)
    # starting mid-segment
    assert tr.time_to_complete(0.5, 1.0) == pytest.approx(0.5)
    assert tr.value_at(2.9) == 0.5 and tr.value_at(3.0) == 4.0


def test_trace_zero_segment_stalls_and_trailing_zero_is_inf():
    tr = piecewise((0.0, 1.0, 2.0), (1.0, 0.0, 1.0))
    # 1.5 work from t=0: 1.0 by t=1, stall on [1,2), finish 0.5 at t=2.5
    assert tr.time_to_complete(0.0, 1.5) == pytest.approx(2.5)
    dead = piecewise((0.0, 1.0), (1.0, 0.0))
    assert math.isinf(dead.time_to_complete(0.5, 1.0))


def test_trace_product_merges_breakpoints():
    a = piecewise((0.0, 2.0), (1.0, 3.0))
    b = piecewise((0.0, 1.0), (2.0, 0.5))
    p = a * b
    for t in (0.0, 0.5, 1.0, 1.5, 2.0, 5.0):
        assert p.value_at(t) == pytest.approx(a.value_at(t) * b.value_at(t))


def test_gauss_markov_stationary_stats():
    rng = np.random.default_rng(0)
    tr = gauss_markov(rng, cv=0.2, dt=1.0, horizon=20000.0, corr=0.9)
    vals = np.asarray(tr.values)
    assert vals.mean() == pytest.approx(1.0, abs=0.03)
    assert vals.std() == pytest.approx(0.2, abs=0.03)
    # correlated: lag-1 autocorrelation near corr
    v = vals - vals.mean()
    rho = (v[:-1] * v[1:]).mean() / (v.var() + 1e-12)
    assert rho == pytest.approx(0.9, abs=0.05)


def test_cv_zero_scenarios_are_constant():
    rng = np.random.default_rng(0)
    assert iid_piecewise(rng, 0.0, dt=1.0, horizon=10.0).is_constant()
    assert gauss_markov(rng, 0.0, dt=1.0, horizon=10.0).is_constant()


# ---------------------------------------------------------------------------
# Scenario injection: stragglers, outages, time-varying capacity
# ---------------------------------------------------------------------------

def test_straggler_window_slows_pipeline(paper_plan):
    prof, net, plan = paper_plan
    base = simulate_plan(prof, net, plan.solution, plan.b, B=plan.B)
    node = plan.solution.placement[1]
    scen = NetworkScenario().with_straggler(node, 0.0, base.L_t, 8.0)
    slow = simulate_plan(prof, net, plan.solution, plan.b, B=plan.B,
                         scenario=scen)
    assert slow.L_t > base.L_t


def test_outage_stalls_transfer(paper_plan):
    prof, net, plan = paper_plan
    base = simulate_plan(prof, net, plan.solution, plan.b, B=plan.B)
    a, c = plan.solution.placement[0], plan.solution.placement[1]
    t_out = 5.0 * base.L_t
    scen = NetworkScenario().with_outage(a, c, 0.0, t_out)
    rep = simulate_plan(prof, net, plan.solution, plan.b, B=plan.B,
                        scenario=scen)
    # the first activation transfer cannot complete before the outage lifts
    assert rep.T_f >= t_out


def test_time_varying_scenarios_run(paper_plan):
    prof, net, plan = paper_plan
    base = simulate_plan(prof, net, plan.solution, plan.b, B=plan.B)
    rng = np.random.default_rng(1)
    for make in (piecewise_cv_scenario, gauss_markov_scenario):
        scen = make(net, 0.3, rng, dt=base.L_t / 16, horizon=4 * base.L_t)
        rep = simulate_plan(prof, net, plan.solution, plan.b, B=plan.B,
                            scenario=scen)
        assert np.isfinite(rep.L_t) and rep.L_t > 0
        assert np.all(np.diff(rep.mb_complete) > -1e-12)


# ---------------------------------------------------------------------------
# Trace-driven fluctuation evaluation (Fig. 6b path)
# ---------------------------------------------------------------------------

def test_fluctuation_trace_mode(paper_plan):
    prof, net, plan = paper_plan
    r0 = evaluate_under_fluctuation(prof, net, plan, 0.0, draws=2, seed=0,
                                    mode="trace")
    assert r0.degradation == pytest.approx(1.0, rel=1e-9)
    for model in ("piecewise", "gauss_markov"):
        r = evaluate_under_fluctuation(prof, net, plan, 0.25, draws=4,
                                       seed=0, mode="trace",
                                       trace_model=model)
        assert np.isfinite(r.mean_latency) and r.mean_latency > 0
        assert r.p95_latency >= r.mean_latency - 1e-12


def test_fluctuation_iid_mode_unchanged(paper_plan):
    """The default path must keep producing the original i.i.d. numbers."""
    import repro.core.latency as L
    prof, net, plan = paper_plan
    r = evaluate_under_fluctuation(prof, net, plan, 0.1, draws=8, seed=3)
    rng = np.random.default_rng(3)
    expect = [L.total_latency(prof, net.with_fluctuation(rng, 0.1),
                              plan.solution, plan.b, plan.B)
              for _ in range(8)]
    assert r.mean_latency == pytest.approx(float(np.mean(expect)), rel=1e-12)


def test_fluctuation_rejects_unknown_mode(paper_plan):
    prof, net, plan = paper_plan
    with pytest.raises(ValueError):
        evaluate_under_fluctuation(prof, net, plan, 0.1, mode="nope")


# ---------------------------------------------------------------------------
# Replanning driven by simulated time
# ---------------------------------------------------------------------------

def test_replanning_driver(paper_plan):
    prof, net, plan = paper_plan
    base = simulate_plan(prof, net, plan.solution, plan.b, B=plan.B)
    node = plan.solution.placement[1]
    trig = [ReplanTrigger(0.4 * base.L_t, Straggler(node, 6.0)),
            ReplanTrigger(0.9 * base.L_t, RateChange(0, node, 0.5))]
    rep = simulate_with_replanning(prof, net, plan.B, trig)
    assert rep.num_replans == 2
    assert np.isfinite(rep.makespan)
    # a straggler + rate drop can only hurt vs the undisturbed run
    assert rep.makespan >= base.L_t - 1e-9
    # every sample is accounted for across segments
    samples = sum(s.completed * s.plan.b for s in rep.segments)
    assert samples >= plan.B
    assert all(s.outcome.action in ("replan", "microbatch")
               for s in rep.segments if s.outcome is not None)


def test_replanning_consumes_scenario_triggers(paper_plan):
    """Triggers composed onto the scenario via with_replan fire too."""
    prof, net, plan = paper_plan
    base = simulate_plan(prof, net, plan.solution, plan.b, B=plan.B)
    node = plan.solution.placement[1]
    scen = NetworkScenario().with_replan(0.5 * base.L_t, Straggler(node, 6.0))
    rep = simulate_with_replanning(prof, net, plan.B, scenario=scen)
    assert rep.num_replans == 1


def test_replanning_rejects_node_failure_with_scenario(paper_plan):
    """NodeFailure renumbers indices; index-keyed scenario traces would
    silently land on the wrong nodes — must be rejected."""
    from repro.ft import NodeFailure
    prof, net, plan = paper_plan
    scen = NetworkScenario().with_straggler(1, 0.0, 1.0, 2.0)
    with pytest.raises(ValueError, match="NodeFailure"):
        simulate_with_replanning(prof, net, plan.B,
                                 [ReplanTrigger(0.01, NodeFailure(2))],
                                 scenario=scen)


def test_replanning_no_triggers_matches_plain_sim(paper_plan):
    prof, net, plan = paper_plan
    rep = simulate_with_replanning(prof, net, plan.B, [])
    plain = simulate_plan(prof, net, rep.coordinator.plan.solution,
                          rep.coordinator.plan.b, B=plan.B)
    assert rep.makespan == pytest.approx(plain.L_t, rel=1e-9)


# ---------------------------------------------------------------------------
# Chrome trace export
# ---------------------------------------------------------------------------

def test_chrome_trace_export(tmp_path, paper_plan):
    prof, net, plan = paper_plan
    rep = simulate_plan(prof, net, plan.solution, plan.b, B=plan.B)
    path = write_chrome_trace(rep.records, str(tmp_path / "trace.json"))
    with open(path) as f:
        data = json.load(f)
    evs = [e for e in data["traceEvents"] if e["ph"] == "X"]
    assert len(evs) == len(rep.records)
    assert all({"name", "ts", "dur", "pid", "tid"} <= set(e) for e in evs)
    names = [e["args"]["name"] for e in data["traceEvents"]
             if e["ph"] == "M"]
    assert any(n.startswith("node0:fp") for n in names)


def test_build_tasks_chain_shape():
    prof, net, sol, b, _ = random_instance(9)
    m = 3
    tasks = build_tasks(prof, net, sol, b, m)
    K = len(list(sol.segments()))
    assert len(tasks) == m * (2 * K + 2 * (K - 1))
    roots = [t for t in tasks if t.dep is None]
    assert len(roots) == m                       # one chain per micro-batch
    assert all(t.resource == ("fp", 0) for t in roots)
