"""End-to-end driver: train the paper's workload with pipelined SL for a few
hundred rounds (scale the round count down with --rounds for CPU).

    PYTHONPATH=src python examples/train_pipeline_sl.py --rounds 20

Covers: multi-client non-IID data (Dirichlet split), the BCD plan, pipelined
execution with int8 link compression, per-round latency accounting, and a
mid-run straggler event handled by the ft coordinator (micro-batch
re-solve, Theorem 1) without restarting training.
"""

import argparse

import jax.numpy as jnp
import numpy as np

from repro.compression import make_link_hooks
from repro.core import make_edge_network, vgg16_profile
from repro.data import client_datasets
from repro.ft import Coordinator, Straggler
from repro.pipeline import SplitLearningExecutor


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--servers", type=int, default=6)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--compress", default="int8",
                    choices=["none", "int8", "topk"])
    ap.add_argument("--iid", action="store_true")
    args = ap.parse_args()

    profile = vgg16_profile(work_units="bytes")
    net = make_edge_network(num_servers=args.servers,
                            num_clients=args.clients, seed=1,
                            kappa=1 / 32.0)
    coord = Coordinator(profile, net, B=args.batch)
    print(f"plan: cuts={coord.plan.solution.cuts} "
          f"placement={coord.plan.solution.placement} b*={coord.plan.b}")

    clients = client_datasets(args.clients, samples=2048, iid=args.iid,
                              alpha=0.5, seed=0)
    hooks = make_link_hooks(args.compress) if args.compress != "none" \
        else None
    ex = SplitLearningExecutor(coord.plan, profile, net, hooks=hooks,
                               seed=0)

    shares = np.full(args.clients, args.batch // args.clients)
    shares[-1] = args.batch - shares[:-1].sum()     # Eq. (1)
    for r in range(args.rounds):
        parts = [c.draw(int(s)) for c, s in zip(clients, shares)]
        batch = {k: jnp.asarray(np.concatenate([p[k] for p in parts]))
                 for k in parts[0]}
        loss = ex.train_round(batch, lr=0.02, momentum=0.9)
        if r == args.rounds // 2:
            # a server slows down mid-training: cheap Theorem-1 re-solve
            node = coord.plan.solution.placement[-1]
            out = coord.apply(Straggler(node=node, slowdown=2.0))
            ex.plan = coord.plan
            ex.round_latency = coord.plan.L_t
            print(f"  [ft] straggler on node {node}: action={out.action}, "
                  f"new b*={coord.plan.b}, L_t={coord.plan.L_t:.4f}s")
        if r % 5 == 0 or r == args.rounds - 1:
            print(f"round {r:3d} loss {loss:.4f} "
                  f"sim-time {ex.simulated_time:8.2f}s")
    print("done.")


if __name__ == "__main__":
    main()
