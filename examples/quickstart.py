"""Quickstart: the paper's pipeline in five steps.

    PYTHONPATH=src python examples/quickstart.py

1. build an edge network + the VGG-16 workload profile (Table II setup)
2. solve the joint MSP + micro-batching problem (Algorithms 1 + 2)
3. compare against the no-pipeline optimum (the paper's headline)
4. validate Eq. (14) against a discrete-event pipeline simulation
5. run one actual pipelined-SL training round on synthetic data
"""

import jax.numpy as jnp

from repro.core import (breakdown, make_edge_network, no_pipeline, ours,
                        num_fills, vgg16_profile)
from repro.data import classification_batches
from repro.pipeline import SplitLearningExecutor, simulate_from_breakdown

# 1. workload + network -------------------------------------------------------
profile = vgg16_profile(work_units="bytes")       # I = 16 layers, Table II
net = make_edge_network(num_servers=6, num_clients=4, seed=1,
                        kappa=1 / 32.0)
print(f"network: {net.num_servers} servers, "
      f"f = {[f'{n.f/1e12:.1f}T' for n in net.servers]} FLOPS")

# 2. plan ----------------------------------------------------------------------
plan = ours(profile, net, B=512, b0=20)
print(f"\nplan: cuts={plan.solution.cuts} placement={plan.solution.placement}"
      f"\n      micro-batch b*={plan.b} ({plan.num_microbatches} "
      f"micro-batches)\n      T_f={plan.T_f:.4f}s T_i={plan.T_i:.4f}s "
      f"L_t={plan.L_t:.4f}s")

# 3. vs no-pipeline ------------------------------------------------------------
np_plan = no_pipeline(profile, net, B=512)
print(f"\nno-pipeline L_t={np_plan.L_t:.4f}s "
      f"-> pipelining speedup {np_plan.L_t / plan.L_t:.2f}x")

# 4. Eq. (14) vs event simulation ----------------------------------------------
q = num_fills(512, plan.b) + 1
sim = simulate_from_breakdown(breakdown(profile, net, plan.solution, plan.b),
                              q)
print(f"\nevent-sim makespan {sim.makespan:.4f}s vs analytic "
      f"{sim.analytic:.4f}s (gap {sim.rel_gap:.2e})")

# 5. one real training round ----------------------------------------------------
small_plan = ours(profile, net, B=16, b0=4)
ex = SplitLearningExecutor(small_plan, profile, net, seed=0)
batch = {k: jnp.asarray(v)
         for k, v in next(classification_batches(batch=16, seed=0)).items()}
loss = ex.train_round(batch, lr=0.05)
print(f"\none pipelined-SL round: loss {loss:.4f}, "
      f"simulated clock +{ex.round_latency:.4f}s")
print("done.")
