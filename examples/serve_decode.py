"""Serve a small model with batched requests (continuous batching).

    PYTHONPATH=src python examples/serve_decode.py --arch qwen3-0.6b
"""

import argparse

import numpy as np

from repro.launch.serve import BatchedServer, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=3)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    srv = BatchedServer(args.arch, reduced=True, batch=args.batch,
                        cache_len=64)
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        srv.submit(Request(
            rid, rng.integers(0, srv.cfg.vocab,
                              args.prompt_len).astype(np.int32),
            max_new=args.gen))
    stats = srv.run()
    for req in stats["completed"]:
        print(f"request {req.rid}: generated {len(req.generated)} tokens "
              f"{req.generated[:8]}...")
    print(f"\n{stats['tokens']} tokens in {stats['seconds']:.1f}s "
          f"({stats['tok_per_s']:.1f} tok/s, batch={args.batch}, "
          f"continuous batching)")


if __name__ == "__main__":
    main()
