"""Simulate a pipelined-SL plan as discrete events + export a Chrome trace.

    PYTHONPATH=src python examples/simulate_pipeline.py

1. plan the paper's Table-II setup with Algorithm 2 (BCD)
2. execute the plan in the event engine; check Eqs. (12)-(14) hold exactly
3. re-run with the vectorized engine and under 1F1B admission (memory
   high-water marks vs the GPipe-like FIFO default)
4. re-run under a straggler window and a link outage
5. drive the elastic ft.Coordinator from *simulated* time (mid-run replan)
6. decompose per-resource idle time (fill/bubble/drain — the Fig. 2
   bubbles, quantified) via obs.UtilizationReport
7. write the deterministic timeline as results/sim/pipeline_trace.json
   with counter tracks, micro-batch flow arrows, and wall-clock solver
   spans (load it at chrome://tracing or https://ui.perfetto.dev)
"""

import json
import os

from repro import obs
from repro.core import make_edge_network, ours, vgg16_profile
from repro.ft import Straggler
from repro.sim import (NetworkScenario, ReplanTrigger, simulate_plan,
                       simulate_with_replanning,
                       stage_activation_highwater, write_chrome_trace)

OUT = os.path.join(os.path.dirname(__file__), "..", "results", "sim")

# telemetry on for the whole walkthrough: planner/BCD/sim spans + counters
obs.enable()

# 1. plan ---------------------------------------------------------------------
profile = vgg16_profile(work_units="bytes")
net = make_edge_network(num_servers=6, num_clients=4, seed=1, kappa=1 / 32.0)
plan = ours(profile, net, B=256, b0=20)
print(f"plan: cuts={plan.solution.cuts} placement={plan.solution.placement}"
      f" b*={plan.b} ({plan.num_microbatches} micro-batches)")
print(f"analytic: T_f={plan.T_f:.5f}s T_i={plan.T_i:.5f}s L_t={plan.L_t:.5f}s")

# 2. deterministic execution --------------------------------------------------
rep = simulate_plan(profile, net, plan.solution, plan.b, B=plan.B)
print(f"simulated: T_f={rep.T_f:.5f}s T_i={rep.T_i:.5f}s L_t={rep.L_t:.5f}s"
      f"  ({len(rep.records)} events)")
gap = abs(rep.L_t - plan.L_t) / plan.L_t
print(f"relative gap vs Eq. (14): {gap:.2e}  "
      f"{'OK' if gap < 1e-6 else 'MISMATCH'}")
bottleneck = max(rep.resource_busy.items(), key=lambda kv: kv[1])
print(f"bottleneck resource: {bottleneck[0]} "
      f"({100 * bottleneck[1]:.1f}% busy)")

# 3. vectorized engine + admission policies -----------------------------------
vec = simulate_plan(profile, net, plan.solution, plan.b, B=plan.B,
                    engine="auto")
gap_v = abs(vec.L_t - rep.L_t) / rep.L_t
print(f"\nvectorized engine ({vec.engine}): L_t={vec.L_t:.5f}s "
      f"(gap vs event engine {gap_v:.2e})")
one = simulate_plan(profile, net, plan.solution, plan.b, B=plan.B,
                    engine="auto", policy="1f1b")
hw_fifo = stage_activation_highwater(rep.records)
hw_1f1b = stage_activation_highwater(one.records)
print(f"1F1B: L_t={one.L_t:.5f}s (+{100 * (one.L_t / rep.L_t - 1):.1f}%)  "
      f"activation high-water per stage: fifo={hw_fifo} -> 1f1b={hw_1f1b}")

# 4. dynamic conditions -------------------------------------------------------
victim = plan.solution.placement[1]
slow = None
for slowdown in (6.0, 60.0):
    scen = NetworkScenario().with_straggler(victim, 0.0, 0.5 * rep.L_t,
                                            slowdown)
    slow = simulate_plan(profile, net, plan.solution, plan.b, B=plan.B,
                         scenario=scen)
    print(f"\nstraggler (node {victim} {slowdown:.0f}x slower for half the "
          f"run): L_t={slow.L_t:.5f}s "
          f"(+{100 * (slow.L_t / rep.L_t - 1):.1f}%)")
print("(a mild straggler off the bottleneck resource costs nothing — the "
      "pipeline absorbs it)")

a, c = plan.solution.placement[0], plan.solution.placement[1]
scen = NetworkScenario().with_outage(a, c, 0.0, 2.0 * plan.T_f)
out = simulate_plan(profile, net, plan.solution, plan.b, B=plan.B,
                    scenario=scen)
print(f"outage (link {a}->{c} dark for 2*T_f): T_f={out.T_f:.5f}s "
      f"L_t={out.L_t:.5f}s")

# 5. mid-run replanning driven by simulated time ------------------------------
rr = simulate_with_replanning(
    profile, net, plan.B,
    [ReplanTrigger(0.4 * rep.L_t, Straggler(victim, 6.0))])
seg = rr.segments[0]
print(f"\nreplan: straggler fires at t={seg.cutoff:.5f}s after "
      f"{seg.completed} micro-batches; coordinator action="
      f"{seg.outcome.action!r}; total makespan={rr.makespan:.5f}s")

# 6. idle-time decomposition --------------------------------------------------
util = rep.utilization()
print(f"\nidle accounting over [0, {util.span:.5f}]s: "
      f"{100 * util.idle_fraction_total:.1f}% idle "
      f"({100 * util.bubble_fraction:.1f}% bubbles, "
      f"{100 * util.fill_drain_fraction:.1f}% fill/drain)")
for node, frac in sorted(util.node_idle_fraction().items()):
    print(f"  node {node}: {100 * (1 - frac):5.1f}% utilized")

# 7. Chrome trace (+ counter tracks, flows, wall-clock solver spans) ----------
path = write_chrome_trace(rep.records,
                          os.path.join(OUT, "pipeline_trace.json"),
                          counter_tracks=True, flow_events=True,
                          wall_spans=obs.wall_spans())
with open(path) as f:
    problems = obs.validate_chrome_trace(json.load(f))
print(f"\nChrome trace -> {os.path.abspath(path)} "
      f"({'valid' if not problems else problems})")

# telemetry summary: what the planner/simulator did, by the numbers
counters = obs.get_registry().snapshot()
print("counters:", json.dumps(counters, indent=2, sort_keys=True))
