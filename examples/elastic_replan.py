"""Elasticity demo: node failure -> BCD re-plan -> checkpointed restart.

    PYTHONPATH=src python examples/elastic_replan.py

The paper's own machinery (Algorithm 2) promoted to a fault-tolerance
runtime: when a server dies, the coordinator rebuilds the network, re-runs
the joint MSP + micro-batching optimization, and the executor resumes from
the full-model checkpoint (submodels are views into the same weights, so
re-splitting costs no state conversion).
"""

import jax.numpy as jnp

from repro.core import make_edge_network, vgg16_profile
from repro.data import classification_batches
from repro.ft import Coordinator, NodeFailure, RateChange
from repro.pipeline import SplitLearningExecutor

profile = vgg16_profile(work_units="bytes")
net = make_edge_network(num_servers=6, num_clients=4, seed=1,
                        kappa=1 / 32.0)
coord = Coordinator(profile, net, B=32)
print(f"initial plan: cuts={coord.plan.solution.cuts} "
      f"placement={coord.plan.solution.placement} L_t={coord.plan.L_t:.4f}s")

ex = SplitLearningExecutor(coord.plan, profile, net, seed=0)
data = classification_batches(batch=32, seed=0)
batch = {k: jnp.asarray(v) for k, v in next(data).items()}

for r in range(3):
    loss = ex.train_round(batch, lr=0.03)
    print(f"round {r}: loss {loss:.4f}")

# a server that hosts a submodel fails
victim = coord.plan.solution.placement[-1]
print(f"\n!! server {victim} fails")
out = coord.apply(NodeFailure(server=victim))
print(f"replan: cuts={coord.plan.solution.cuts} "
      f"placement={coord.plan.solution.placement} "
      f"L_t={coord.plan.L_t:.4f}s (was {out.old_latency:.4f}s)")

# the executor re-splits the SAME weights per the new plan and continues
weights = ex.full_params                       # checkpointed full model
ex = SplitLearningExecutor(coord.plan, profile, coord.net, seed=0)
ex.full_params = weights
for r in range(3, 6):
    loss = ex.train_round(batch, lr=0.03)
    print(f"round {r}: loss {loss:.4f} (resumed on degraded network)")

# a link degrades: replan again
out = coord.apply(RateChange(n_from=1, n_to=2, factor=0.1))
print(f"\nlink 1->2 degraded 10x: new L_t={coord.plan.L_t:.4f}s "
      f"(action={out.action})")
print("done.")
