# Tier-1 verification + dev conveniences.
# `make verify` is the full tier-1 suite (includes known seed-debt
# failures); CI runs `make verify-ci`, which deselects them (see
# .github/workflows/ci.yml).

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: verify verify-ci verify-docs test dev-deps sim-check bench-fig6b \
        bench-sweep example-sim

verify:
	$(PYTHON) -m pytest -x -q

# pre-existing jax failures present since the seed (see ROADMAP.md "Seed
# debt"); CI deselects them so it signals on *new* breakage, while the
# tier-1 `verify` target keeps the debt visible locally
KNOWN_FAILURES := \
  --deselect tests/test_hlo.py::test_xla_counts_loop_bodies_once \
  --deselect tests/test_hlo.py::test_collective_parser_on_sharded_module \
  --deselect tests/test_spmd.py::test_pipeline_loss_and_grads_match_plain \
  --deselect tests/test_spmd.py::test_checkpoint_reshards_across_meshes \
  --deselect tests/test_spmd.py::test_small_mesh_train_step_lowers_with_production_rules \
  --deselect tests/test_system.py::test_end_to_end_sl_training_converges

verify-ci:
	$(PYTHON) -m pytest -x -q $(KNOWN_FAILURES)

# modules whose docstrings carry runnable >>> examples (the ISSUE 2
# docstring pass); --doctest-modules is the package-aware `python -m
# doctest` (relative imports need the package context)
DOCTEST_MODULES := \
  src/repro/sim/engine.py src/repro/sim/events.py src/repro/sim/policies.py \
  src/repro/sim/scenario.py src/repro/sim/validate.py \
  src/repro/core/bcd.py src/repro/core/microbatch.py \
  src/repro/pipeline/schedule.py

# docs job: doctests over the documented APIs + the docs/*.md anchor/link
# check + export hygiene; reuses the tier-1 deselect list above so it
# signals on the same breakage set as verify-ci
verify-docs:
	$(PYTHON) -m pytest -q $(KNOWN_FAILURES) --doctest-modules \
	  $(DOCTEST_MODULES) tests/test_docs.py tests/test_exports.py

test:
	$(PYTHON) -m pytest -q

dev-deps:
	$(PYTHON) -m pip install -r requirements-dev.txt

# fast standalone consistency check: event engine vs Eqs. (12)-(14)
sim-check:
	$(PYTHON) -m pytest -q tests/test_sim.py

bench-fig6b:
	$(PYTHON) -m benchmarks.fig6b_traces

# topology x fluctuation x admission-policy sweep + engine-scaling grid
bench-sweep:
	$(PYTHON) -m benchmarks.sweep_grid

example-sim:
	$(PYTHON) examples/simulate_pipeline.py
