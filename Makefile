# Tier-1 verification + dev conveniences.
# The 6 pre-existing jax-0.4.37 seed-debt failures (test_hlo / test_spmd /
# test_system) are annotated in-place as xfail(strict=False) with root-cause
# notes (ISSUE 3 satellite), so `make verify` is green while the debt stays
# visible as `x` in the report — no deselect list needed anymore.

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: verify verify-ci verify-docs test dev-deps sim-check fuzz bench \
        bench-planner bench-costmodel bench-sim bench-robustness bench-ft \
        bench-adaptive bench-fig6b bench-sweep bench-obs example-sim

verify:
	$(PYTHON) -m pytest -x -q

verify-ci: verify

# modules whose docstrings carry runnable >>> examples (the ISSUE 2
# docstring pass); --doctest-modules is the package-aware `python -m
# doctest` (relative imports need the package context)
DOCTEST_MODULES := \
  src/repro/sim/engine.py src/repro/sim/events.py src/repro/sim/policies.py \
  src/repro/sim/scenario.py src/repro/sim/validate.py \
  src/repro/sim/advance.py src/repro/sim/fuzz.py src/repro/sim/robustness.py \
  src/repro/core/bcd.py src/repro/core/cost_model.py \
  src/repro/core/microbatch.py \
  src/repro/ft/policy.py src/repro/ft/adaptive.py \
  src/repro/pipeline/schedule.py

# docs job: doctests over the documented APIs + the docs/*.md anchor/link
# check + export hygiene
verify-docs:
	$(PYTHON) -m pytest -q --doctest-modules \
	  $(DOCTEST_MODULES) tests/test_docs.py tests/test_exports.py

test:
	$(PYTHON) -m pytest -q

dev-deps:
	$(PYTHON) -m pip install -r requirements-dev.txt

# fast standalone consistency check: event engine vs Eqs. (12)-(14)
sim-check:
	$(PYTHON) -m pytest -q tests/test_sim.py

# fixed-seed differential fuzz campaign + CVaR selection smoke: shrunk
# parity breakers land in tests/corpus/, summary CSVs in results/bench/
fuzz:
	$(PYTHON) -m benchmarks.bench_robustness --smoke

# planner scaling grid + the ISSUE-3 acceptance instance; rewrites the
# repo-root BENCH_planner.json perf-trajectory file
bench-planner:
	$(PYTHON) -m benchmarks.bench_planner

# closed-form vs sim-refined BCD on reentrant/memory-starved instances;
# rewrites the repo-root BENCH_costmodel.json trajectory file
bench-costmodel:
	$(PYTHON) -m benchmarks.bench_costmodel

# trace-aware engine scaling + sim-in-the-loop solve overhead;
# rewrites the repo-root BENCH_sim.json trajectory file
bench-sim:
	$(PYTHON) -m benchmarks.bench_sim

# 500-case fuzz parity campaign + robust-vs-nominal plan selection;
# rewrites the repo-root BENCH_robustness.json trajectory file
bench-robustness:
	$(PYTHON) -m benchmarks.bench_robustness

# replan-policy zoo on the fixed-seed flap corpus + the Periodic-cadence vs
# Gauss-Markov-drift frontier; rewrites the repo-root BENCH_ft.json file
bench-ft:
	$(PYTHON) -m benchmarks.bench_ft_policy

# adaptive-cadence vs fixed-cadence regimes, tail-sized admission under
# fuzzed memory pressure, and the successive-halving policy tuner;
# rewrites the repo-root BENCH_adaptive.json trajectory file
bench-adaptive:
	$(PYTHON) -m benchmarks.bench_adaptive

bench: bench-planner bench-costmodel bench-sim bench-robustness bench-ft \
       bench-adaptive bench-fig6b bench-sweep bench-obs

# telemetry overhead on the 10k-micro-batch acceptance chain: asserts the
# enabled-mode slowdown stays < 5% and disabled mode is a true no-op
bench-obs:
	$(PYTHON) -m benchmarks.bench_obs

bench-fig6b:
	$(PYTHON) -m benchmarks.fig6b_traces

# topology x fluctuation x admission-policy sweep + engine-scaling grid
bench-sweep:
	$(PYTHON) -m benchmarks.sweep_grid

example-sim:
	$(PYTHON) examples/simulate_pipeline.py
